//! Random feature maps: `Φ(x) s.t. Φ(x)ᵀΦ(y) ≈ κ(x, y)`.
//!
//! Each map wraps a [`Transform`] (Gaussian or TripleSpin) and a pointwise
//! nonlinearity. The Gaussian kernel uses the paired cos/sin Rahimi–Recht
//! features; the angular kernel uses sign features (a PNG with `f = sign`);
//! the arc-cosine kernel uses `√2·ReLU` features.

use crate::linalg::Workspace;
use crate::runtime::pool::{shard_rows, WorkerPool};
use crate::transform::Transform;

/// The nonlinearity / kernel selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FeatureKind {
    /// Rahimi–Recht RFF for the Gaussian kernel: projections scaled by
    /// `1/σ`, features `[cos(Gx/σ); sin(Gx/σ)] / √k` (2 features per row).
    GaussianRff,
    /// Sign features for the angular kernel `1 - 2θ/π`.
    Angular,
    /// `√2·max(0, ·)` features for the (normalized) first-order arc-cosine
    /// kernel.
    ArcCosine1,
}

/// A feature map built from a projection transform and a nonlinearity.
pub struct FeatureMap {
    transform: Box<dyn Transform>,
    kind: FeatureKind,
    /// Gaussian-kernel bandwidth σ (ignored by the other kinds).
    sigma: f64,
}

impl FeatureMap {
    /// `transform.dim_out()` projection rows; GaussianRff emits
    /// `2 * dim_out()` features (cos and sin per projection).
    pub fn new(transform: Box<dyn Transform>, kind: FeatureKind, sigma: f64) -> FeatureMap {
        assert!(sigma > 0.0);
        FeatureMap {
            transform,
            kind,
            sigma,
        }
    }

    /// Input dimensionality the underlying transform expects.
    pub fn dim_in(&self) -> usize {
        self.transform.dim_in()
    }

    /// Feature dimensionality.
    pub fn dim_features(&self) -> usize {
        match self.kind {
            FeatureKind::GaussianRff => 2 * self.transform.dim_out(),
            _ => self.transform.dim_out(),
        }
    }

    /// Projection dimensionality `k` (= the 1-bit code width of
    /// [`FeatureMap::binary_codes_into`]).
    pub fn dim_projection(&self) -> usize {
        self.transform.dim_out()
    }

    pub fn kind(&self) -> FeatureKind {
        self.kind
    }

    /// Compute `Φ(x)` into `out` (`out.len() == dim_features()`), drawing
    /// every intermediate buffer from `ws` — the zero-allocation hot path.
    /// Inputs shorter than `dim_in()` are zero-padded (Hadamard families
    /// need power-of-two dims).
    pub fn features_into(&self, x: &[f32], out: &mut [f32], ws: &mut Workspace) {
        let n = self.transform.dim_in();
        assert!(x.len() <= n, "input dim {} exceeds transform dim {n}", x.len());
        debug_assert_eq!(out.len(), self.dim_features());
        let k = self.transform.dim_out();
        let mut proj = ws.take_f32_uninit(k); // OVERWRITE: fully overwritten below
        self.transform.apply_padded_into(x, &mut proj, ws);
        self.nonlin_into(&proj, out);
        ws.put_f32(proj);
    }

    /// Pointwise nonlinearity stage: `proj` rows of `dim_out()` to feature
    /// rows of `dim_features()`.
    fn nonlin_into(&self, proj: &[f32], out: &mut [f32]) {
        let k = proj.len();
        match self.kind {
            FeatureKind::GaussianRff => {
                debug_assert_eq!(out.len(), 2 * k);
                let scale = (1.0 / k as f64).sqrt() as f32;
                let inv_sigma = (1.0 / self.sigma) as f32;
                let (cos_half, sin_half) = out.split_at_mut(k);
                for (o, v) in cos_half.iter_mut().zip(proj) {
                    *o = (v * inv_sigma).cos() * scale;
                }
                for (o, v) in sin_half.iter_mut().zip(proj) {
                    *o = (v * inv_sigma).sin() * scale;
                }
            }
            FeatureKind::Angular => {
                let scale = (1.0 / k as f64).sqrt() as f32;
                for (o, v) in out.iter_mut().zip(proj) {
                    *o = if *v >= 0.0 { scale } else { -scale };
                }
            }
            FeatureKind::ArcCosine1 => {
                let scale = (2.0 / k as f64).sqrt() as f32;
                for (o, v) in out.iter_mut().zip(proj) {
                    *o = v.max(0.0) * scale;
                }
            }
        }
    }

    /// Compute `Φ(x)`. Thin allocating wrapper over
    /// [`FeatureMap::features_into`].
    pub fn features(&self, x: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; self.dim_features()];
        let mut ws = Workspace::new();
        self.features_into(x, &mut out, &mut ws);
        out
    }

    /// Batch-first feature map: `xs` holds `rows` row-major inputs of
    /// `dim_in()` (already padded), `out` receives `rows` feature rows. The
    /// projection runs through the transform's persistent-pool batch
    /// engine; the projection scratch comes from the pool's serial
    /// workspace, so repeated batches through the same pool are
    /// allocation-free once warm.
    pub fn features_batch_into(&self, xs: &[f32], out: &mut [f32], pool: &WorkerPool) {
        let n = self.transform.dim_in();
        debug_assert_eq!(xs.len() % n, 0);
        let rows = xs.len() / n;
        let d = self.dim_features();
        debug_assert_eq!(out.len(), rows * d);
        let k = self.transform.dim_out();
        // OVERWRITE: apply_batch_into writes every row of the projection.
        let mut proj = pool.with_serial_workspace(|ws| ws.take_f32_uninit(rows * k));
        self.transform.apply_batch_into(xs, &mut proj, pool);
        // pointwise stage sharded too: for GaussianRff the cos/sin pass is
        // comparable to the projection itself, so leaving it serial would
        // give back half the multi-core win
        {
            let proj_ref: &[f32] = &proj;
            let out_ptr = out.as_mut_ptr() as usize;
            // ~8 work units per emitted feature (cos/sin transcendentals
            // dominate the pointwise stage)
            shard_rows(pool, rows, 8 * d, &|lo, hi, _slot, _ws| {
                let pc = &proj_ref[lo * k..hi * k];
                // SAFETY: disjoint covering row ranges, joined before return.
                let oc = unsafe {
                    std::slice::from_raw_parts_mut(
                        (out_ptr as *mut f32).add(lo * d),
                        (hi - lo) * d,
                    )
                };
                for (prow, orow) in pc.chunks_exact(k).zip(oc.chunks_exact_mut(d)) {
                    self.nonlin_into(prow, orow);
                }
            });
        }
        pool.with_serial_workspace(move |ws| ws.put_f32(proj));
    }

    /// Allocating wrapper over [`FeatureMap::features_batch_into`] on the
    /// process-wide pool.
    pub fn features_batch(&self, xs: &[f32]) -> Vec<f32> {
        let n = self.transform.dim_in();
        debug_assert_eq!(xs.len() % n, 0);
        let rows = xs.len() / n;
        let mut out = vec![0.0f32; rows * self.dim_features()];
        self.features_batch_into(xs, &mut out, WorkerPool::global());
        out
    }

    /// Approximate kernel value `Φ(x)ᵀΦ(y)`.
    pub fn approx_kernel(&self, x: &[f32], y: &[f32]) -> f64 {
        let fx = self.features(x);
        let fy = self.features(y);
        crate::linalg::vecops::dot(&fx, &fy)
    }

    /// 1-bit feature code: the sign bits of the raw projection `Gx`,
    /// packed into `u64` words (`⌈dim_out/64⌉` of them) — the binarized
    /// feature-map path, routed through the shared
    /// [`crate::binary::pack_projection_into`] primitive. For
    /// [`FeatureKind::Angular`] this is the sign feature vector quantized
    /// losslessly to one bit per projection (the ±scale magnitude carries
    /// no information), so the 1-bit Gram estimate
    /// [`FeatureMap::approx_kernel_1bit`] reproduces the dense angular
    /// estimate exactly; for the other kinds it estimates the angular
    /// kernel of the same projection at 1/32 the bytes.
    pub fn binary_codes_into(&self, x: &[f32], out: &mut [u64], ws: &mut Workspace) {
        crate::binary::pack_projection_into(self.transform.as_ref(), x, out, ws);
    }

    /// Allocating wrapper over [`FeatureMap::binary_codes_into`].
    pub fn binary_codes(&self, x: &[f32]) -> crate::binary::BitVec {
        let mut ws = Workspace::new();
        let k = self.transform.dim_out();
        let mut words = vec![0u64; k.div_ceil(64)];
        self.binary_codes_into(x, &mut words, &mut ws);
        crate::binary::BitVec::from_words(words, k)
    }

    /// Batch 1-bit codes: `rows` inputs of `dim_in()` (already padded) to
    /// one packed code row each, through the shared fused pool-sharded
    /// [`crate::binary::pack_projection_batch_into`] (the float projection
    /// of the batch is never materialized). Bit-identical per row to
    /// [`FeatureMap::binary_codes_into`].
    pub fn binary_codes_batch_into(
        &self,
        xs: &[f32],
        out: &mut crate::binary::BitMatrix,
        pool: &WorkerPool,
    ) {
        crate::binary::pack_projection_batch_into(self.transform.as_ref(), xs, out, pool);
    }

    /// 1-bit Gram estimate between two codes from
    /// [`FeatureMap::binary_codes_into`]: `1 - 2·d_H/k` — one XOR/popcount
    /// sweep per pair, no float features materialized. Pinned against the
    /// dense [`FeatureKind::Angular`] estimate in the tests below.
    pub fn approx_kernel_1bit(&self, a: &[u64], b: &[u64]) -> f64 {
        crate::binary::angular_estimate(
            crate::linalg::simd::hamming(a, b),
            self.transform.dim_out(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::exact;
    use crate::transform::{make, Family};
    use crate::util::rng::Rng;

    fn avg_kernel_error(fam: Family, kind: FeatureKind, sigma: f64, trials: u64) -> f64 {
        let n = 64;
        let k = 256;
        let mut rng = Rng::new(50);
        let x = rng.unit_vec(n);
        let mut y = x.clone();
        // y at moderate angle from x
        for (i, v) in y.iter_mut().enumerate() {
            *v = 0.8 * *v + 0.2 * if i % 2 == 0 { 0.1 } else { -0.1 };
        }
        crate::linalg::vecops::normalize(&mut y);
        let exact_val = match kind {
            FeatureKind::GaussianRff => exact::gaussian(&x, &y, sigma),
            FeatureKind::Angular => exact::angular(&x, &y),
            FeatureKind::ArcCosine1 => exact::arc_cosine1(&x, &y),
        };
        let mut err = 0.0;
        for t in 0..trials {
            let tr = make(fam, k, n, n, &mut Rng::new(100 + t));
            let fm = FeatureMap::new(tr, kind, sigma);
            err += (fm.approx_kernel(&x, &y) - exact_val).abs();
        }
        err / trials as f64
    }

    #[test]
    fn gaussian_rff_unbiased_dense() {
        let e = avg_kernel_error(Family::Dense, FeatureKind::GaussianRff, 1.0, 8);
        assert!(e < 0.08, "avg |err| = {e}");
    }

    #[test]
    fn gaussian_rff_unbiased_hd3() {
        let e = avg_kernel_error(Family::Hd3, FeatureKind::GaussianRff, 1.0, 8);
        assert!(e < 0.08, "avg |err| = {e}");
    }

    #[test]
    fn angular_features_match_dense_and_structured() {
        for fam in [Family::Dense, Family::Hd3, Family::Toeplitz] {
            let e = avg_kernel_error(fam, FeatureKind::Angular, 1.0, 8);
            assert!(e < 0.12, "{fam:?}: avg |err| = {e}");
        }
    }

    #[test]
    fn arc_cosine_features_approximate() {
        let e = avg_kernel_error(Family::Dense, FeatureKind::ArcCosine1, 1.0, 8);
        assert!(e < 0.12, "avg |err| = {e}");
    }

    #[test]
    fn rff_self_kernel_is_one() {
        // Φ(x)ᵀΦ(x) = Σ (cos² + sin²)/k = 1 exactly for RFF.
        let n = 32;
        let tr = make(Family::Hdg, 64, n, n, &mut Rng::new(1));
        let fm = FeatureMap::new(tr, FeatureKind::GaussianRff, 2.0);
        let x = Rng::new(2).unit_vec(n);
        assert!((fm.approx_kernel(&x, &x) - 1.0).abs() < 1e-5);
    }

    #[test]
    fn feature_dims() {
        let n = 32;
        let tr = make(Family::Hd3, 48, n, n, &mut Rng::new(1));
        let fm = FeatureMap::new(tr, FeatureKind::GaussianRff, 1.0);
        assert_eq!(fm.dim_features(), 96);
        let x = Rng::new(2).unit_vec(n);
        assert_eq!(fm.features(&x).len(), 96);

        let tr2 = make(Family::Hd3, 48, n, n, &mut Rng::new(1));
        let fm2 = FeatureMap::new(tr2, FeatureKind::Angular, 1.0);
        assert_eq!(fm2.dim_features(), 48);
    }

    #[test]
    fn batch_features_match_rowwise_bitwise() {
        let n = 32;
        let rows = 40; // enough rows for the sharded nonlinearity path
        for kind in [
            FeatureKind::GaussianRff,
            FeatureKind::Angular,
            FeatureKind::ArcCosine1,
        ] {
            let tr = make(Family::Toeplitz, 48, n, 16, &mut Rng::new(9));
            let fm = FeatureMap::new(tr, kind, 1.5);
            let xs = Rng::new(10).gaussian_vec(rows * n);
            let batch = fm.features_batch(&xs);
            assert_eq!(batch.len(), rows * fm.dim_features());
            for (r, row) in xs.chunks_exact(n).enumerate() {
                let single = fm.features(row);
                assert_eq!(
                    &batch[r * fm.dim_features()..(r + 1) * fm.dim_features()],
                    &single[..],
                    "{kind:?} row {r}"
                );
            }
        }
    }

    #[test]
    fn one_bit_gram_estimate_pinned_against_dense_angular() {
        // For Angular sign features the 1-bit code is a lossless
        // quantization: 1 - 2·d_H/k must reproduce the dense Φ(x)ᵀΦ(y)
        // estimate up to f32 dot-product round-off, for every family.
        let n = 64;
        let k = 256;
        for fam in [Family::Dense, Family::Hd3, Family::Toeplitz] {
            let tr = make(fam, k, n, n, &mut Rng::new(70));
            let fm = FeatureMap::new(tr, FeatureKind::Angular, 1.0);
            let mut rng = Rng::new(71);
            for _ in 0..5 {
                let x = rng.unit_vec(n);
                let y = rng.unit_vec(n);
                let dense = fm.approx_kernel(&x, &y);
                let cx = fm.binary_codes(&x);
                let cy = fm.binary_codes(&y);
                let one_bit = fm.approx_kernel_1bit(cx.words(), cy.words());
                assert!(
                    (dense - one_bit).abs() < 1e-4,
                    "{fam:?}: dense {dense} vs 1-bit {one_bit}"
                );
                // and the code is 32x smaller than the feature vector
                assert_eq!(cx.storage_bytes(), k / 8);
            }
        }
    }

    #[test]
    fn binary_codes_batch_matches_rowwise_bitwise() {
        let n = 32;
        let rows = 40;
        let tr = make(Family::Hdg, 96, n, 16, &mut Rng::new(13));
        let fm = FeatureMap::new(tr, FeatureKind::Angular, 1.0);
        let xs = Rng::new(14).gaussian_vec(rows * n);
        let pool = crate::runtime::WorkerPool::with_min_work(4, 0);
        let mut batch = crate::binary::BitMatrix::zeros(rows, 96);
        fm.binary_codes_batch_into(&xs, &mut batch, &pool);
        for (r, row) in xs.chunks_exact(n).enumerate() {
            let single = fm.binary_codes(row);
            assert_eq!(batch.row(r), single.words(), "row {r}");
        }
    }

    #[test]
    fn short_inputs_zero_padded() {
        let n = 64;
        let tr = make(Family::Hd3, n, n, n, &mut Rng::new(3));
        let fm = FeatureMap::new(tr, FeatureKind::Angular, 1.0);
        let x50 = Rng::new(4).unit_vec(50);
        let f = fm.features(&x50); // no panic, padded internally
        assert_eq!(f.len(), n);
    }
}
