//! # TripleSpin
//!
//! A production-quality reproduction of *"TripleSpin — a generic compact
//! paradigm for fast machine learning computations"* (Choromanski, Fagan,
//! Gouy-Pailler, Morvan, Sarlos, Atif; 2016).
//!
//! TripleSpin matrices `G_struct = M3 · M2 · M1` (e.g. `HD3·HD2·HD1`,
//! `HDg·HD2·HD1`, `Gcirc·D2·HD1`, Toeplitz/Hankel/skew-circulant variants)
//! replace dense i.i.d. Gaussian projection matrices in randomized ML
//! algorithms: matvecs drop from `Θ(mn)` to `O(n log n)` and storage from
//! `O(mn)` to `O(n)` (or just random bits for the fully discrete chain),
//! with provably small accuracy loss.
//!
//! ## Execution engine
//!
//! The hot path is **zero-allocation, batch-first, pool-resident and
//! SIMD-dispatched**. Every [`transform::Transform`] computes through
//! [`transform::Transform::apply_into`], drawing all scratch from a reused
//! [`linalg::Workspace`] (zeroed checkouts for padding-reliant buffers,
//! dirty `take_*_uninit` checkouts for fully-overwritten ones); batches go
//! through [`transform::Transform::apply_batch_into`], which runs each
//! family's batch kernel (row-resident multi-stage pipelines, the
//! twiddle-table multi-row FFT of [`linalg::fft::ConvPlan`]) and
//! distributes rows over the persistent [`runtime::WorkerPool`] by atomic
//! chunk claiming (work stealing — a slow worker gates at most one chunk).
//!
//! The circulant/Toeplitz/Hankel/skew families convolve through a
//! **real-input half-spectrum FFT engine** by default: an `n`-point RFFT
//! computed as an `n/2`-point radix-4 complex FFT plus a conjugate
//! split/merge, with `n/2 + 1`-bin kernel spectra and a fused
//! split·multiply·merge pass ([`linalg::simd::cmul_half`]) — half the
//! butterflies, spectrum and scratch of the legacy full-complex path,
//! which stays compiled and selectable via `TS_FFT=complex` as the A/B
//! baseline and CI cross-check lane (see [`linalg::fft`]).
//! Worker threads spawn once and keep one pinned workspace each for their
//! lifetime, env-tunable via `TS_WORKERS` (`0` = single-threaded), so
//! steady state performs zero thread spawns and zero heap allocations per
//! batch.
//!
//! All arithmetic inner loops (FWHT butterflies, complex FFT butterflies
//! and spectrum multiplies, diagonal passes) dispatch at runtime through
//! [`linalg::simd`] — AVX2/SSE2/NEON with an always-compiled scalar path
//! (`TS_NO_SIMD=1`), every level **bit-identical**. Rademacher diagonals
//! are stored as packed [`transform::SignDiag`] `u64` bitmasks (~`n` bits
//! per discrete diagonal instead of `32n`; see
//! [`transform::Transform::stored_bits`]) and applied as SIMD sign XORs.
//! The allocating `apply` / `apply_batch` remain as thin wrappers.
//! `cargo bench --bench transform_throughput` records per-row-loop vs
//! serial-batch vs pooled-batch speedups plus a `simd_vs_scalar` sweep and
//! a sign-xor diagonal micro in `BENCH_transform_throughput.json`.
//!
//! ## Binary lane
//!
//! The paper's compressibility pillar — "certain models … apply only bit
//! matrices" — is served end to end by the [`binary`] subsystem:
//! sign-quantized embeddings `sign(G_struct x)` packed into `u64` words
//! ([`binary::BitVec`] / [`binary::BitMatrix`], quantization fused into
//! the last transform stage via [`linalg::simd::pack_signs`]), popcount
//! Hamming distances ([`linalg::simd::hamming`], AVX2/scalar tiers,
//! bit-identical), a Hamming LSH index bucketing on packed prefixes
//! ([`lsh::HammingLsh`]), 1-bit Gram estimates in [`kernels`], and a
//! `binary_embed` serving op whose responses are 32× smaller than the
//! f32 lane's. With a discrete family the whole model is bits end to end:
//! ~`3n` parameter bits ([`transform::Transform::stored_bits`]) and `m`
//! output bits per embedding ([`binary::BinaryEmbedding::output_bits`]).
//!
//! ## Fault-isolated serving
//!
//! The serving stack treats the backend as untrusted: every backend batch
//! call runs under `catch_unwind`, a panicking batch is retried as
//! singletons so one poisoned input cannot fail its batchmates, and a
//! lane-fatal invariant violation (malformed output shape) kills only that
//! lane's thread — a supervisor counts the death, fails submits fast with
//! `LaneDown`, and restarts the lane with bounded exponential backoff.
//! Requests carry optional deadlines (dropped with a typed `Deadline`
//! error before backend time is spent once expired), each lane has a
//! consecutive-failure circuit breaker (`Unavailable` fail-fast shedding
//! with half-open probing), and the `health` / `metrics` wire ops expose
//! per-lane state (`open` / `degraded` / `dead-restarting`) and the
//! failure counters. Deterministic chaos comes from
//! [`coordinator::FaultInjectingBackend`]
//! (`TS_FAULT=panic:p,err:p,delay_ms:d,seed:s`), driven by
//! `rust/tests/chaos_serving.rs` and the `serving_fault` bench sweep
//! (error-path latency is measured, not assumed zero).
//!
//! ## Serving ingress
//!
//! In front of the coordinator sits an opt-in coalescing ingress
//! ([`coordinator::Batcher`], enabled via
//! `CoordinatorService::with_ingress` and on by default in `serve --tcp`)
//! that turns many small concurrent requests into the batch shapes the
//! engine is built for:
//!
//! * **Micro-batching** — requests with the same *batch class* (op +
//!   transform configuration, i.e. the `(op, n)` lane: same family chain,
//!   sigma and seed) coalesce in the lane queue and flush as one pooled
//!   backend batch when `max_batch` fills or a short `max_wait` window
//!   closes. The window is cost-model-aware: `Config::flush_work` caps the
//!   estimated work (`coordinator::admission::request_work`) a batch may
//!   accumulate so one huge row never waits on stragglers, and the
//!   earliest per-request deadline in the batch bounds the flush window.
//! * **In-flight dedup** — identical requests (fingerprint =
//!   [`router::topology::request_key`], FNV-1a over the op name and the
//!   exact input bits) share one computation: the first becomes the
//!   *leader*, later arrivals subscribe to its response slot. This is
//!   sound because compute is deterministic in (op, input bits) — SIMD
//!   tiers are bit-identical and lane parameters are seed-fixed — and
//!   because only *successes* fan out: a leader refusal or failure orphans
//!   the slot and each follower retries for itself, so a shed or
//!   throttled follower can never evict the leader's computation and a
//!   poisoned row still fails alone through the panic-singleton-retry
//!   path.
//! * **Response cache** — a bounded per-lane LRU keyed by the same
//!   fingerprint answers exact repeats without backend time; requests opt
//!   out with `"no_cache": true` on the wire. Every request — leader,
//!   follower or cache hit — still pays admission (token bucket, shedder,
//!   drain, breaker) first, so refusal behavior is identical to the
//!   uncoalesced path. `coalesced_rows`, `dedup_followers`,
//!   `cache_hits` / `cache_misses` / `cache_evictions` and the
//!   `cache_entries` occupancy gauge flow through `metrics`, `health` and
//!   the Prometheus text exposition.
//!
//! ## Overload protection
//!
//! Refusing work is a feature with a contract, not an accident:
//!
//! * **Cost-aware admission** — [`coordinator::AdmissionControl`] is a
//!   per-client token bucket denominated in *work units* from
//!   `coordinator::admission::request_work`, the same `O(n log n)` cost
//!   model the batcher uses, so one client hammering `n=4096` transforms
//!   spends its budget ~10× faster than one sending `n=256`. Clients are
//!   keyed by the request's `client_id` field (peer address fallback);
//!   over budget means a `throttled` refusal.
//! * **Adaptive shedding** — [`coordinator::OverloadShedder`] watches
//!   admission→dequeue queue delay per lane (CoDel-style: sustained time
//!   above a target, not instantaneous spikes). Under sustained overload
//!   it sheds lowest-`priority` requests first (`overloaded` refusals),
//!   escalating to normal priority if delay keeps climbing; high priority
//!   (2) is never shed. One sub-target observation resets it.
//! * **Graceful drain** — `TcpServer::begin_drain` / `shutdown_graceful`
//!   (SIGTERM/Ctrl-C in the serve CLI): new connections and new requests
//!   get `draining` refusals while in-flight work finishes under a drain
//!   deadline; queued jobs past the deadline get typed `deadline` answers.
//!   Nothing admitted is ever silently dropped.
//! * **The retry contract** — exactly the retryable codes (`busy`,
//!   `unavailable`, `lane_down`, `throttled`, `overloaded`, `draining` —
//!   [`coordinator::client::RETRYABLE_CODES`]) carry a `retry_after_ms`
//!   hint on the wire; terminal codes (`bad_request`, `bad_dim`, …) never
//!   do. [`coordinator::RetryClient`] honors it with hint-floored
//!   full-jitter exponential backoff under a retry *budget*, so a
//!   persistent outage degrades to fast typed failures instead of a
//!   client-side retry storm. Transport chaos (`TS_FAULT`
//!   `conn_drop:p,slow_read_ms:d,partial_write:p`, applied at the socket
//!   layer) proves every logical request still reaches exactly one
//!   terminal outcome.
//!
//! ## Fleet tier
//!
//! One node is still one failure domain, so the serving stack scales out
//! by treating *whole shards* as untrusted and individually failable.
//! The connection core is transport-agnostic
//! ([`coordinator::server::LineService`] + [`coordinator::server::serve`];
//! the request/response codec lives in [`coordinator::codec`]), so the
//! same accept loop serves three tiers: a single-node
//! [`coordinator::CoordinatorService`], a [`router::ShardService`] (a
//! coordinator plus a bucket-prefix-range slice of the fleet LSH index —
//! see [`router::shard`] for the placement scheme and the
//! union-equals-global exactness argument), and the
//! [`router::ShardRouter`] front-end. The router routes compute ops to
//! their rendezvous-hash owner group (stable under membership change) and
//! fails over through replicas and fallback groups on transport errors,
//! retryable refusals, and timeouts; `lsh_query` scatter-gathers every
//! group with per-group hedged duplicates after an adaptive p95 delay
//! ([`router::hedge::HedgePolicy`]) and merges with
//! [`router::topology::merge_topk`] into the exact global top-k. Shards
//! missing at the scatter budget **degrade, never block**: the reply is a
//! `partial` success naming them in `degraded` — and only a fully dark
//! fleet yields a typed `shard_down` refusal (retryable, with
//! `retry_after_ms`). Per-endpoint circuit breakers reuse the lane
//! breaker ([`coordinator::breaker::LaneState`]); background health
//! probes ([`router::health::Prober`]) are the recovery path that closes
//! them. `metrics` / `health` / `metrics_text` report fleet counters
//! (relays, failovers, hedges and wins, full/partial/shard_down) plus
//! per-endpoint wire counters and breaker phases — `metrics_text` in the
//! Prometheus text exposition ([`coordinator::prom`]), round-trip tested.
//! Whole-shard chaos (`TS_FAULT=down_after_ms:t,down_for_ms:d`) drives
//! the `shard_*` suite in `rust/tests/chaos_serving.rs`: with one of
//! three shards killed mid-load every query still reaches exactly one
//! terminal outcome — full, partial-with-marker, or a typed refusal —
//! and results recover to full once the shard returns.
//!
//! ## Correctness tooling
//!
//! The invariants the engine lives by are machine-checked in layers:
//!
//! * **`cargo xtask lint`** — the repo-native static pass (first, fastest
//!   CI gate). Every `unsafe` needs an adjacent `// SAFETY:` rationale and
//!   may only appear in the allowlisted modules; every non-counter atomic
//!   needs `// ORDERING:`; every `take_*_uninit` dirty checkout needs
//!   `// OVERWRITE:`; every public [`linalg::simd`] kernel must be named
//!   in `tests/simd_equivalence.rs`; wire error codes must be unique and
//!   match ROADMAP's failure-model table. The linter is self-testing
//!   (`cargo test -p xtask`) and mirrored for toolchain-less environments
//!   by `tools/lint_mirror.py`.
//! * **`#![deny(unsafe_op_in_unsafe_fn)]`** — every unsafe operation sits
//!   in an explicit `unsafe {}` block with its own justification, even
//!   inside `unsafe fn`s.
//! * **loom** — `RUSTFLAGS="--cfg loom" cargo test --lib loom` replays
//!   every interleaving of the two lock-free hot spots (the
//!   [`coordinator`] circuit breaker and the [`runtime`] chunk-claim
//!   sharder) through the `util::sync` atomics façade; see `loom_models`.
//! * **Miri** — `MIRIFLAGS=-Zmiri-disable-isolation TS_NO_SIMD=1 cargo
//!   miri test` (unit tests, `#[cfg(miri)]`-shrunk sizes) checks the
//!   uninit-checkout and packed-bit paths for UB.
//! * **ThreadSanitizer** — nightly `RUSTFLAGS=-Zsanitizer=thread` over
//!   the threaded pool/coordinator tests; `tools/bench_mirror.c` runs its
//!   startup self-tests under `-fsanitize=address,undefined`.
//!
//! ## Layout
//!
//! * [`util`] / [`linalg`] — substrates: seeded RNG, JSON, bench/property
//!   harnesses; FWHT, FFT-based structured matvecs, dense baselines, and
//!   the [`linalg::Workspace`] scratch arenas.
//! * [`transform`] — the TripleSpin family itself (the paper's §3),
//!   including block stacking (§3.1).
//! * [`binary`] — packed binary embeddings: sign-quantized feature maps,
//!   bit-matrix storage, Hamming-distance machinery (the bit-matrix
//!   mobile-footprint story).
//! * [`kernels`] — random-feature kernel approximation (paper §4):
//!   Gaussian/angular/arc-cosine and general PNG kernels, Gram-matrix
//!   reconstruction metrics, plus the 1-bit binarized feature path.
//! * [`lsh`] — cross-polytope LSH (paper §2/§5, Figure 1) and the packed
//!   Hamming-prefix index.
//! * [`sketch`] — Newton sketch for convex optimization (paper §6.3,
//!   Figure 3), with logistic regression.
//! * [`data`] — synthetic datasets standing in for USPST / G50C and the
//!   logistic-regression design matrices (substitutions in DESIGN.md §4).
//! * [`runtime`] — the persistent batch [`runtime::WorkerPool`], plus the
//!   PJRT executor loading `artifacts/*.hlo.txt` that
//!   `python/compile/aot.py` lowered from the JAX/Pallas layers.
//! * [`coordinator`] — L3 serving layer: request router, dynamic batcher,
//!   worker pool, metrics, backpressure, lane supervision (panic
//!   isolation, circuit breaker, deadline propagation, fault injection);
//!   ops `transform` / `rff` / `crosspolytope` / `binary_embed` (plus
//!   `metrics` / `health` / `metrics_text` introspection) over
//!   newline-JSON TCP.
//! * [`router`] — the fleet tier above: shard topology + rendezvous
//!   routing, per-endpoint health/breakers, hedged scatter-gather with
//!   partial-result degradation, and the shard-side index slice.

// Every unsafe *operation* must sit in an explicit `unsafe {}` block with
// its own `// SAFETY:` rationale — an `unsafe fn` signature alone does not
// discharge the obligation. Enforced together with `cargo xtask lint`.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod binary;
pub mod coordinator;
pub mod data;
pub mod jlt;
pub mod kernels;
pub mod linalg;
// Exhaustive interleaving models of the breaker and the chunk-claim
// sharder; compiled only under `RUSTFLAGS="--cfg loom"` (loom CI lane).
#[cfg(loom)]
mod loom_models;
pub mod lsh;
pub mod quantize;
pub mod router;
pub mod runtime;
pub mod sketch;
pub mod transform;
pub mod util;
