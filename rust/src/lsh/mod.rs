//! Cross-polytope Locality-Sensitive Hashing (paper §2, Figure 1,
//! Theorem 5.3).
//!
//! The hash of a unit vector is the closest signed canonical direction of
//! its (normalized) random projection: `h(x) = η(Gx / ||Gx||)`. Replacing
//! the Gaussian `G` with `HD3·HD2·HD1` keeps the collision-probability
//! curve (Theorem 5.3 bounds the total-variation gap over convex sets) while
//! hashing in `O(n log n)`.

//! A binary sibling lives alongside: [`hamming::HammingLsh`] buckets on
//! packed sign-code prefixes and re-ranks by popcount, serving the same
//! queries from 1-bit codes (see [`crate::binary`]).

pub mod collision;
pub mod crosspolytope;
pub mod hamming;
pub mod index;

pub use crosspolytope::CrossPolytopeHash;
pub use hamming::HammingLsh;
pub use index::LshIndex;
