//! Cross-polytope Locality-Sensitive Hashing (paper §2, Figure 1,
//! Theorem 5.3).
//!
//! The hash of a unit vector is the closest signed canonical direction of
//! its (normalized) random projection: `h(x) = η(Gx / ||Gx||)`. Replacing
//! the Gaussian `G` with `HD3·HD2·HD1` keeps the collision-probability
//! curve (Theorem 5.3 bounds the total-variation gap over convex sets) while
//! hashing in `O(n log n)`.

pub mod collision;
pub mod crosspolytope;
pub mod index;

pub use crosspolytope::CrossPolytopeHash;
pub use index::LshIndex;
