//! The cross-polytope hash function `h(x) = η(Gx / ||Gx||₂)`.
//!
//! `η(y)` returns the closest vector among `{±e_i}` — equivalently the index
//! of the largest-|·| coordinate together with its sign. Normalization does
//! not change the argmax, so the hash needs only one transform apply plus
//! one linear scan.

use crate::linalg::vecops::argmax_abs_signed;
use crate::linalg::Workspace;
use crate::runtime::WorkerPool;
use crate::transform::{make_square, Family, Transform};
use crate::util::rng::Rng;

/// One cross-polytope hash function over `R^n`.
///
/// Hash values live in `0..2n`: value `i < n` encodes `+e_i`, value
/// `i >= n` encodes `-e_{i-n}`.
pub struct CrossPolytopeHash {
    transform: Box<dyn Transform>,
}

impl CrossPolytopeHash {
    pub fn new(transform: Box<dyn Transform>) -> CrossPolytopeHash {
        CrossPolytopeHash { transform }
    }

    /// Standard square construction of the given family (the paper's
    /// Figure 1 setting).
    pub fn with_family(family: Family, n: usize, rng: &mut Rng) -> CrossPolytopeHash {
        CrossPolytopeHash {
            transform: make_square(family, n, rng),
        }
    }

    /// Input dimensionality (inputs shorter than this are zero-padded).
    pub fn dim(&self) -> usize {
        self.transform.dim_in()
    }

    /// Number of distinct hash buckets (`2 * dim_out`).
    pub fn num_buckets(&self) -> usize {
        2 * self.transform.dim_out()
    }

    /// Hash a vector with caller-owned scratch — the zero-allocation path
    /// the LSH index drives (one workspace shared across every table, hash
    /// function and point).
    pub fn hash_with(&self, x: &[f32], ws: &mut Workspace) -> usize {
        let mut y = ws.take_f32_uninit(self.transform.dim_out()); // OVERWRITE: fully overwritten
        self.transform.apply_padded_into(x, &mut y, ws);
        let h = argmax_abs_signed(&y);
        ws.put_f32(y);
        h
    }

    /// Hash a vector. The norm of `x` is irrelevant (hash is scale
    /// invariant), matching the unit-sphere setting of the paper. Thin
    /// wrapper over [`CrossPolytopeHash::hash_with`].
    pub fn hash(&self, x: &[f32]) -> usize {
        let mut ws = Workspace::new();
        self.hash_with(x, &mut ws)
    }

    /// Hash a row-major batch (`rows` inputs of `dim()`, already padded)
    /// into `out`, projecting every row through the persistent worker
    /// pool's batch engine — the bulk-index-build path. Bit-identical per
    /// row to [`CrossPolytopeHash::hash_with`].
    pub fn hash_batch(&self, xs: &[f32], out: &mut [usize], pool: &WorkerPool) {
        let n = self.transform.dim_in();
        let k = self.transform.dim_out();
        debug_assert_eq!(xs.len() % n, 0);
        let rows = xs.len() / n;
        debug_assert_eq!(out.len(), rows);
        // OVERWRITE: apply_batch_into writes every row of the projection.
        let mut proj = pool.with_serial_workspace(|ws| ws.take_f32_uninit(rows * k));
        self.transform.apply_batch_into(xs, &mut proj, pool);
        for (o, prow) in out.iter_mut().zip(proj.chunks_exact(k)) {
            *o = argmax_abs_signed(prow);
        }
        pool.with_serial_workspace(move |ws| ws.put_f32(proj));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::for_all;

    #[test]
    fn hash_in_range_and_scale_invariant() {
        for_all(24, |g| {
            let n = g.pow2_in(2, 7);
            let fam = *g.choose(&[Family::Dense, Family::Hd3, Family::Hdg]);
            let h = CrossPolytopeHash::with_family(fam, n, &mut Rng::new(g.u64()));
            let x = g.gaussian_vec(n);
            let b = h.hash(&x);
            assert!(b < h.num_buckets());
            let scaled: Vec<f32> = x.iter().map(|v| v * 7.5).collect();
            assert_eq!(h.hash(&scaled), b, "hash must be scale invariant");
        });
    }

    #[test]
    fn identical_points_always_collide() {
        for_all(16, |g| {
            let n = 32;
            let h = CrossPolytopeHash::with_family(Family::Hd3, n, &mut Rng::new(g.u64()));
            let x = g.unit_vec(n);
            assert_eq!(h.hash(&x), h.hash(&x));
        });
    }

    #[test]
    fn antipodal_points_never_collide() {
        // h(-x) is the opposite bucket of h(x).
        for_all(16, |g| {
            let n = 32;
            let h = CrossPolytopeHash::with_family(Family::Hdg, n, &mut Rng::new(g.u64()));
            let x = g.unit_vec(n);
            let neg: Vec<f32> = x.iter().map(|v| -v).collect();
            let (a, b) = (h.hash(&x), h.hash(&neg));
            assert_ne!(a, b);
            // and specifically the sign-flipped encoding of the same index
            let m = n;
            assert_eq!(a % m, b % m);
        });
    }

    #[test]
    fn buckets_roughly_uniform_for_random_input() {
        // Averaged over hash draws, a random input lands in each of the 2n
        // buckets with equal probability (symmetry of the construction).
        let n = 8;
        let mut counts = vec![0usize; 2 * n];
        let mut rng = Rng::new(2);
        let draws = 40;
        let per = 250;
        for d in 0..draws {
            let h = CrossPolytopeHash::with_family(Family::Dense, n, &mut Rng::new(d));
            for _ in 0..per {
                counts[h.hash(&rng.unit_vec(n))] += 1;
            }
        }
        let trials = draws as usize * per;
        let expect = trials as f64 / (2 * n) as f64;
        for (i, c) in counts.iter().enumerate() {
            assert!(
                (*c as f64 - expect).abs() < 5.0 * expect.sqrt() + 0.05 * expect,
                "bucket {i}: {c} vs expected {expect}"
            );
        }
    }

    #[test]
    fn short_input_padded() {
        let h = CrossPolytopeHash::with_family(Family::Hd3, 64, &mut Rng::new(3));
        let x = Rng::new(4).unit_vec(50);
        assert!(h.hash(&x) < 128);
    }
}
