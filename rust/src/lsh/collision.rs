//! Collision-probability estimation — the measurement behind Figure 1.
//!
//! For each distance bucket we draw pairs of unit vectors at that exact
//! Euclidean distance (distance `d` on the unit sphere ⇔ inner product
//! `1 - d²/2`), hash both with freshly drawn hash functions, and count
//! collisions.

use super::crosspolytope::CrossPolytopeHash;
use crate::linalg::vecops::normalize;
use crate::transform::Family;
use crate::util::rng::Rng;

/// Draw a pair of unit vectors in `R^n` at Euclidean distance `dist`
/// (`0 <= dist <= 2`).
pub fn pair_at_distance(n: usize, dist: f64, rng: &mut Rng) -> (Vec<f32>, Vec<f32>) {
    // x random unit; y = c·x + s·w with w ⟂ x unit, c = 1 - d²/2, s = √(1-c²).
    let c = 1.0 - dist * dist / 2.0;
    let s = (1.0 - c * c).max(0.0).sqrt();
    let x = rng.unit_vec(n);
    // random unit vector orthogonal to x
    let mut w = rng.unit_vec(n);
    let proj: f64 = x.iter().zip(&w).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
    for (wi, xi) in w.iter_mut().zip(&x) {
        *wi -= (proj as f32) * *xi;
    }
    normalize(&mut w);
    let y: Vec<f32> = x
        .iter()
        .zip(&w)
        .map(|(xi, wi)| (c as f32) * xi + (s as f32) * wi)
        .collect();
    (x, y)
}

/// One row of the Figure-1 sweep.
#[derive(Clone, Debug)]
pub struct CollisionPoint {
    pub distance: f64,
    pub probability: f64,
}

/// Estimate the collision curve of `family` over `distances`, using
/// `hash_draws` independent hash functions × `pairs_per_draw` pairs each
/// (the paper: 100 runs × 20 000 points).
pub fn collision_curve(
    family: Family,
    n: usize,
    distances: &[f64],
    hash_draws: u64,
    pairs_per_draw: usize,
    seed: u64,
) -> Vec<CollisionPoint> {
    let mut out = Vec::with_capacity(distances.len());
    for (di, &dist) in distances.iter().enumerate() {
        let mut collisions = 0usize;
        let mut total = 0usize;
        for h in 0..hash_draws {
            let hash = CrossPolytopeHash::with_family(
                family,
                n,
                &mut Rng::new(seed ^ (h * 1_000_003 + di as u64)),
            );
            let mut rng = Rng::new(seed.wrapping_add(77).wrapping_add(h * 13 + di as u64 * 7919));
            for _ in 0..pairs_per_draw {
                let (x, y) = pair_at_distance(n, dist, &mut rng);
                if hash.hash(&x) == hash.hash(&y) {
                    collisions += 1;
                }
                total += 1;
            }
        }
        out.push(CollisionPoint {
            distance: dist,
            probability: collisions as f64 / total as f64,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vecops::{euclidean, norm2};
    use crate::util::prop::for_all;

    #[test]
    fn pair_at_distance_is_exact() {
        for_all(24, |g| {
            let n = g.usize_in(4, 128);
            let d = g.f32_in(0.05, 1.95) as f64;
            let mut rng = Rng::new(g.u64());
            let (x, y) = pair_at_distance(n, d, &mut rng);
            assert!((norm2(&x) - 1.0).abs() < 1e-4);
            assert!((norm2(&y) - 1.0).abs() < 1e-3);
            assert!(
                (euclidean(&x, &y) - d).abs() < 1e-3,
                "wanted dist {d}, got {}",
                euclidean(&x, &y)
            );
        });
    }

    #[test]
    fn collision_probability_decreases_with_distance() {
        let n = 64;
        let distances = [0.2, 0.8, 1.4, 1.9];
        for fam in [Family::Dense, Family::Hd3] {
            let curve = collision_curve(fam, n, &distances, 20, 50, 42);
            for w in curve.windows(2) {
                assert!(
                    w[0].probability >= w[1].probability - 0.02,
                    "{fam:?}: p({}) = {} < p({}) = {}",
                    w[0].distance,
                    w[0].probability,
                    w[1].distance,
                    w[1].probability
                );
            }
            assert!(curve[0].probability > 0.3, "{fam:?}: near pairs should collide often");
            assert!(curve[3].probability < 0.1, "{fam:?}: far pairs should rarely collide");
        }
    }

    #[test]
    fn structured_curve_close_to_unstructured() {
        // Theorem 5.3's empirical face: the HD3 curve tracks the Gaussian
        // curve pointwise.
        let n = 64;
        let distances = [0.3, 0.9, 1.5];
        let dense = collision_curve(Family::Dense, n, &distances, 30, 60, 7);
        let hd3 = collision_curve(Family::Hd3, n, &distances, 30, 60, 7);
        for (a, b) in dense.iter().zip(&hd3) {
            assert!(
                (a.probability - b.probability).abs() < 0.08,
                "at d={}: dense {} vs hd3 {}",
                a.distance,
                a.probability,
                b.probability
            );
        }
    }
}
