//! Hamming-distance LSH over packed binary embeddings.
//!
//! The binary counterpart of the cross-polytope [`super::index::LshIndex`]:
//! instead of bucketing on the argmax of a float projection, every table
//! sign-quantizes a short structured projection
//! ([`crate::binary::BinaryEmbedding`]) and buckets on the **packed
//! prefix** — a `prefix_bits`-bit code is one `u64` word, used as the
//! bucket key directly, no float ever stored. Candidates from matching
//! buckets are re-ranked by popcount Hamming distance against a full-width
//! code per point ([`crate::linalg::simd::hamming`]), so the entire index
//! — parameters, stored points and query arithmetic — is bit matrices and
//! XOR/popcount.
//!
//! Per-bit collision behaves like SimHash: two unit vectors at angle `θ`
//! disagree on each code bit with probability exactly `θ/π`, so expected
//! normalized Hamming distance is `θ/π` and a `b`-bit prefix bucket
//! collides with probability `(1 - θ/π)^b` (independent projections) —
//! pinned against the angular-distance oracle in
//! `tests/binary_embedding.rs`.

use crate::binary::{BinaryEmbedding, BitMatrix};
use crate::linalg::Workspace;
use crate::runtime::WorkerPool;
use crate::transform::{make, Family};
use crate::util::rng::Rng;
use std::collections::HashMap;

/// One table: a `prefix_bits`-bit binary embedding whose single packed
/// word is the bucket key.
struct Table {
    embed: BinaryEmbedding,
    buckets: HashMap<u64, Vec<usize>>,
}

impl Table {
    fn key(&self, q: &[f32], ws: &mut Workspace) -> u64 {
        let mut code = [0u64; 1];
        self.embed.embed_into(q, &mut code, ws);
        code[0]
    }
}

/// Multi-table Hamming LSH index over packed codes.
pub struct HammingLsh {
    tables: Vec<Table>,
    /// Full-width re-ranking embedding (one `code_bits`-bit code per point).
    coder: BinaryEmbedding,
    codes: BitMatrix,
}

impl HammingLsh {
    /// Build over `points` (dims `<= n`, zero-padded): `l` tables bucketing
    /// on `prefix_bits`-bit packed prefixes (`1..=64`), re-ranking against
    /// `n`-bit full codes. All projections run as bulk batches over the
    /// persistent worker pool.
    pub fn build(
        points: &[Vec<f32>],
        family: Family,
        n: usize,
        l: usize,
        prefix_bits: usize,
        seed: u64,
    ) -> HammingLsh {
        assert!(
            (1..=64).contains(&prefix_bits),
            "prefix_bits must be in 1..=64 (one packed word), got {prefix_bits}"
        );
        let mut master = Rng::new(seed);
        let coder = BinaryEmbedding::with_family(family, n, &mut master.fork());
        let mut tables: Vec<Table> = (0..l)
            .map(|_| Table {
                // stacked/truncated shape: exactly prefix_bits code bits
                embed: BinaryEmbedding::new(make(family, prefix_bits, n, n, &mut master.fork())),
                buckets: HashMap::new(),
            })
            .collect();

        let rows = points.len();
        let pool = WorkerPool::global();
        let flat = crate::linalg::dense::flatten_padded(points, n);
        let mut codes = BitMatrix::zeros(rows, n);
        coder.embed_batch_into(&flat, &mut codes, pool);
        let mut prefix = BitMatrix::zeros(rows, prefix_bits);
        for tb in tables.iter_mut() {
            tb.embed.embed_batch_into(&flat, &mut prefix, pool);
            for i in 0..rows {
                tb.buckets.entry(prefix.row(i)[0]).or_default().push(i);
            }
        }
        HammingLsh {
            tables,
            coder,
            codes,
        }
    }

    pub fn len(&self) -> usize {
        self.codes.rows()
    }

    pub fn is_empty(&self) -> bool {
        self.codes.is_empty()
    }

    /// Width of the re-ranking codes in bits.
    pub fn code_bits(&self) -> usize {
        self.codes.bits()
    }

    /// Total packed bytes the index's point payload occupies (codes only —
    /// the mobile-footprint number; no float points are retained).
    pub fn storage_bytes(&self) -> usize {
        self.codes.storage_bytes()
    }

    /// Candidate set: union of the query's prefix buckets, deduplicated
    /// (sorted ascending). Cost scales with the candidate count, not the
    /// index size — no O(N) seen-bitmap sweep per query.
    pub fn candidates(&self, q: &[f32]) -> Vec<usize> {
        self.candidates_with(q, &mut Workspace::new())
    }

    /// [`HammingLsh::candidates`] with caller-owned scratch — one
    /// workspace serves every table's prefix embed.
    fn candidates_with(&self, q: &[f32], ws: &mut Workspace) -> Vec<usize> {
        let mut out = Vec::new();
        for tb in &self.tables {
            if let Some(ids) = tb.buckets.get(&tb.key(q, ws)) {
                out.extend_from_slice(ids);
            }
        }
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Approximate k-NN: candidates re-ranked by popcount Hamming distance
    /// between full codes. Returns `(index, hamming)` pairs, nearest
    /// first. One workspace threads through the full-code embed and every
    /// table key.
    pub fn query(&self, q: &[f32], k: usize) -> Vec<(usize, u64)> {
        let mut ws = Workspace::new();
        let mut qcode = vec![0u64; self.coder.words_per_code()];
        self.coder.embed_into(q, &mut qcode, &mut ws);
        let mut cands: Vec<(usize, u64)> = self
            .candidates_with(q, &mut ws)
            .into_iter()
            .map(|i| (i, self.codes.hamming_to(i, &qcode)))
            .collect();
        cands.sort_by_key(|(i, d)| (*d, *i));
        cands.truncate(k);
        cands
    }

    /// Exact k-NN in code space by brute-force popcount scan (recall
    /// baseline — still no float arithmetic).
    pub fn brute_force(&self, q: &[f32], k: usize) -> Vec<(usize, u64)> {
        let mut ws = Workspace::new();
        let mut qcode = vec![0u64; self.coder.words_per_code()];
        self.coder.embed_into(q, &mut qcode, &mut ws);
        let mut all: Vec<(usize, u64)> = (0..self.len())
            .map(|i| (i, self.codes.hamming_to(i, &qcode)))
            .collect();
        all.sort_by_key(|(i, d)| (*d, *i));
        all.truncate(k);
        all
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lsh::collision::pair_at_distance;

    fn cluster_dataset(n: usize, clusters: usize, per: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        let mut pts = Vec::new();
        for _ in 0..clusters {
            let center = rng.unit_vec(n);
            for _ in 0..per {
                let (_, nearby) = pair_at_distance(n, 0.25, &mut rng);
                let mut p: Vec<f32> = center
                    .iter()
                    .zip(&nearby)
                    .map(|(c, q)| 0.9 * c + 0.1 * q)
                    .collect();
                crate::linalg::vecops::normalize(&mut p);
                pts.push(p);
            }
        }
        pts
    }

    #[test]
    fn finds_exact_duplicates_at_distance_zero() {
        let n = 64;
        let pts = cluster_dataset(n, 4, 20, 1);
        let idx = HammingLsh::build(&pts, Family::Hd3, n, 8, 12, 99);
        assert_eq!(idx.len(), 80);
        assert_eq!(idx.code_bits(), n);
        for i in [0usize, 17, 40, 79] {
            let res = idx.query(&pts[i], 1);
            assert!(!res.is_empty(), "point {i} not found in any bucket");
            assert_eq!(res[0].0, i);
            assert_eq!(res[0].1, 0, "self-query must be at Hamming distance 0");
        }
    }

    #[test]
    fn recall_reasonable_on_clustered_data() {
        let n = 64;
        let pts = cluster_dataset(n, 5, 30, 2);
        let idx = HammingLsh::build(&pts, Family::Hd3, n, 10, 10, 7);
        let mut rng = Rng::new(3);
        let mut hits = 0;
        let trials = 30;
        for _ in 0..trials {
            let qi = rng.below(pts.len() as u64) as usize;
            let mut q = pts[qi].clone();
            q[0] += 0.05;
            crate::linalg::vecops::normalize(&mut q);
            // oracle and query both rank in code space — this isolates the
            // bucketing loss from the quantization loss
            let truth = idx.brute_force(&q, 1)[0].0;
            if idx.query(&q, 1).first().map(|r| r.0) == Some(truth) {
                hits += 1;
            }
        }
        let recall = hits as f64 / trials as f64;
        assert!(recall > 0.6, "recall@1 = {recall}");
    }

    #[test]
    fn candidates_dedup_and_in_range() {
        let n = 32;
        let pts = cluster_dataset(n, 3, 10, 4);
        let idx = HammingLsh::build(&pts, Family::Hdg, n, 6, 8, 8);
        let c = idx.candidates(&pts[0]);
        let mut sorted = c.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), c.len(), "candidates must be deduplicated");
        assert!(c.iter().all(|i| *i < pts.len()));
    }

    #[test]
    fn storage_is_codes_only() {
        let n = 128;
        let pts = cluster_dataset(n, 2, 16, 5);
        let idx = HammingLsh::build(&pts, Family::Hd3, n, 4, 16, 6);
        // 32 points × 128 bits = 512 bytes of payload — 1/32 of the f32
        // point set the cross-polytope index retains
        assert_eq!(idx.storage_bytes(), 32 * n / 8);
    }

    #[test]
    fn empty_index() {
        let idx = HammingLsh::build(&[], Family::Hd3, 16, 2, 8, 1);
        assert!(idx.is_empty());
        assert_eq!(idx.len(), 0);
        assert!(idx.query(&[0.0; 16], 3).is_empty());
    }

    #[test]
    fn prefix_bits_bounds_enforced() {
        let r = std::panic::catch_unwind(|| {
            HammingLsh::build(&[], Family::Hd3, 16, 1, 65, 1);
        });
        assert!(r.is_err(), "prefix_bits > 64 must be rejected");
    }
}
