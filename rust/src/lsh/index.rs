//! Multi-table LSH index for approximate nearest-neighbor search.
//!
//! The downstream application the paper's LSH section motivates: `L` tables,
//! each keyed by the concatenation of `t` cross-polytope hashes. Queries
//! collect candidates from all tables and re-rank them exactly.

use super::crosspolytope::CrossPolytopeHash;
use crate::linalg::vecops::euclidean;
use crate::linalg::Workspace;
use crate::runtime::WorkerPool;
use crate::transform::Family;
use crate::util::rng::Rng;
use std::collections::HashMap;

/// FNV-1a offset basis / prime used to combine the `t` sub-hashes of one
/// table into a single 64-bit bucket key.
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x1000_0000_01b3;

/// One hash table: `t` concatenated hash functions.
struct Table {
    hashes: Vec<CrossPolytopeHash>,
    buckets: HashMap<u64, Vec<usize>>,
}

impl Table {
    fn key(&self, x: &[f32], ws: &mut Workspace) -> u64 {
        // combine the t sub-hashes into one 64-bit key
        let mut k = FNV_OFFSET;
        for h in &self.hashes {
            k ^= h.hash_with(x, ws) as u64;
            k = k.wrapping_mul(FNV_PRIME);
        }
        k
    }
}

/// Multi-probe-free, multi-table cross-polytope LSH index.
pub struct LshIndex {
    tables: Vec<Table>,
    points: Vec<Vec<f32>>,
}

impl LshIndex {
    /// Build an index over `points` with `l` tables × `t` hashes each.
    pub fn build(
        points: Vec<Vec<f32>>,
        family: Family,
        n: usize,
        l: usize,
        t: usize,
        seed: u64,
    ) -> LshIndex {
        let mut master = Rng::new(seed);
        let mut tables: Vec<Table> = (0..l)
            .map(|_| Table {
                hashes: (0..t)
                    .map(|_| CrossPolytopeHash::with_family(family, n, &mut master.fork()))
                    .collect(),
                buckets: HashMap::new(),
            })
            .collect();
        // Bulk build: every (table, hash) projects the whole point set in
        // one sweep over the persistent worker pool — batch-level FWHT/FFT
        // kernels plus multi-core sharding instead of per-point applies.
        // Key combination matches Table::key exactly (FNV over sub-hashes).
        let rows = points.len();
        let pool = WorkerPool::global();
        let flat = crate::linalg::dense::flatten_padded(points, n);
        let mut codes = vec![0usize; rows];
        for tb in tables.iter_mut() {
            let mut keys = vec![FNV_OFFSET; rows];
            for h in &tb.hashes {
                h.hash_batch(&flat, &mut codes, pool);
                for (k, c) in keys.iter_mut().zip(&codes) {
                    *k ^= *c as u64;
                    *k = k.wrapping_mul(FNV_PRIME);
                }
            }
            for (i, k) in keys.iter().enumerate() {
                tb.buckets.entry(*k).or_default().push(i);
            }
        }
        LshIndex { tables, points }
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Candidate set for a query (union of matching buckets, deduplicated).
    pub fn candidates(&self, q: &[f32]) -> Vec<usize> {
        let mut seen = vec![false; self.points.len()];
        let mut out = Vec::new();
        let mut ws = Workspace::new();
        for tb in &self.tables {
            if let Some(ids) = tb.buckets.get(&tb.key(q, &mut ws)) {
                for &i in ids {
                    if !seen[i] {
                        seen[i] = true;
                        out.push(i);
                    }
                }
            }
        }
        out
    }

    /// Approximate k-NN: re-rank candidates by exact distance. Returns
    /// `(index, distance)` pairs, nearest first.
    pub fn query(&self, q: &[f32], k: usize) -> Vec<(usize, f64)> {
        let mut cands: Vec<(usize, f64)> = self
            .candidates(q)
            .into_iter()
            .map(|i| (i, euclidean(q, &self.points[i])))
            .collect();
        cands.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        cands.truncate(k);
        cands
    }

    /// Exact k-NN by brute force (recall baseline).
    pub fn brute_force(&self, q: &[f32], k: usize) -> Vec<(usize, f64)> {
        let mut all: Vec<(usize, f64)> = self
            .points
            .iter()
            .enumerate()
            .map(|(i, p)| (i, euclidean(q, p)))
            .collect();
        all.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        all.truncate(k);
        all
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lsh::collision::pair_at_distance;

    fn cluster_dataset(n: usize, clusters: usize, per: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        let mut pts = Vec::new();
        for _ in 0..clusters {
            let center = rng.unit_vec(n);
            for _ in 0..per {
                // small perturbation around the center, re-normalized
                let (_, nearby) = pair_at_distance(n, 0.25, &mut rng);
                let mut p: Vec<f32> = center
                    .iter()
                    .zip(&nearby)
                    .map(|(c, q)| 0.9 * c + 0.1 * q)
                    .collect();
                crate::linalg::vecops::normalize(&mut p);
                pts.push(p);
            }
        }
        pts
    }

    #[test]
    fn index_finds_exact_duplicates() {
        let n = 64;
        let pts = cluster_dataset(n, 4, 20, 1);
        let idx = LshIndex::build(pts.clone(), Family::Hd3, n, 8, 1, 99);
        // querying with an indexed point must return it at distance 0
        for i in [0usize, 17, 40, 79] {
            let res = idx.query(&pts[i], 1);
            assert!(!res.is_empty(), "point {i} not found in any bucket");
            assert_eq!(res[0].0, i);
            assert!(res[0].1 < 1e-9);
        }
    }

    #[test]
    fn recall_reasonable_on_clustered_data() {
        let n = 64;
        let pts = cluster_dataset(n, 5, 30, 2);
        let idx = LshIndex::build(pts.clone(), Family::Hd3, n, 10, 1, 7);
        let mut rng = Rng::new(3);
        let mut hits = 0;
        let trials = 30;
        for _ in 0..trials {
            let qi = rng.below(pts.len() as u64) as usize;
            // perturb the query slightly off an indexed point
            let mut q = pts[qi].clone();
            q[0] += 0.05;
            crate::linalg::vecops::normalize(&mut q);
            let truth = idx.brute_force(&q, 1)[0].0;
            let approx = idx.query(&q, 1);
            if approx.first().map(|r| r.0) == Some(truth) {
                hits += 1;
            }
        }
        let recall = hits as f64 / trials as f64;
        assert!(recall > 0.6, "recall@1 = {recall}");
    }

    #[test]
    fn candidates_subset_and_dedup() {
        let n = 32;
        let pts = cluster_dataset(n, 3, 10, 4);
        let idx = LshIndex::build(pts.clone(), Family::Hdg, n, 6, 1, 8);
        let c = idx.candidates(&pts[0]);
        let mut sorted = c.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), c.len(), "candidates must be deduplicated");
        assert!(c.iter().all(|i| *i < pts.len()));
    }

    #[test]
    fn empty_and_len() {
        let idx = LshIndex::build(Vec::new(), Family::Hd3, 16, 2, 1, 1);
        assert!(idx.is_empty());
        assert_eq!(idx.len(), 0);
        assert!(idx.query(&[0.0; 16], 3).is_empty());
    }
}
