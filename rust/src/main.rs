//! `triplespin` — leader binary / CLI.
//!
//! Subcommands:
//!   info                      list compiled artifacts + lanes
//!   verify                    run every artifact against its golden vectors
//!   serve [opts]              start the coordinator and drive a workload
//!                             (--shard i/N turns it into one fleet shard)
//!   route [opts]              shard-router front-end over a fleet of shards
//!   transform [opts]          one-shot structured transform of a random vector
//!   metrics-demo              short burst + metrics JSON dump
//!
//! Run `triplespin help` for the option list. The binary is self-contained
//! once `make artifacts` has produced `artifacts/` (PJRT backend); the
//! native backend needs no artifacts at all.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use triplespin::coordinator::{
    Backend, Config, Coordinator, FaultInjectingBackend, NativeBackend, PjrtBackend,
};
use triplespin::runtime::{Op, RuntimeService};
use triplespin::transform::{make_square, Family};
use triplespin::util::rng::Rng;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let opts = parse_opts(&args[args.len().min(1)..]);
    let code = match cmd {
        "info" => cmd_info(&opts),
        "verify" => cmd_verify(&opts),
        "serve" => cmd_serve(&opts),
        "route" => cmd_route(&opts),
        "transform" => cmd_transform(&opts),
        "metrics-demo" => cmd_metrics_demo(&opts),
        "help" | "--help" | "-h" => {
            print_help();
            0
        }
        other => {
            eprintln!("unknown command '{other}'\n");
            print_help();
            2
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!(
        "triplespin — structured random matrices for fast ML computations

USAGE: triplespin <command> [--key value]...

COMMANDS:
  info            list artifacts in --artifacts (default: artifacts/)
  verify          execute every artifact against its Python golden vectors
  serve           start coordinator; drive --requests N at --rate req/s
                  (--backend native|pjrt, --n 256,
                   --op transform|rff|crosspolytope|binary_embed,
                   --max-batch 64, --queue 1024,
                   --max-wait-us 200 | --max-wait-ms W (coalescing window),
                   --flush-work U [0 = off] closes a batch early at U
                   estimated work units (huge rows don't wait on stragglers),
                   --deadline-ms 0 [0 = none], --breaker-threshold 8,
                   --breaker-cooldown-ms 250)
                  --tcp ADDR serves newline-JSON instead; then:
                   --max-conns 256 [0 = unlimited],
                   --drain-deadline-ms 5000 (SIGTERM/Ctrl-C drain),
                   --admit-rate R work-units/s per client [0 = off],
                   --admit-burst B [0 = R], --shed-target-ms T [0 = off],
                   --shed-window-ms 100,
                   --cache-cap 256 response-cache entries per lane [0 = off],
                   --no-dedup disables in-flight request dedup
                  --shard I/N makes this node shard I of an N-shard fleet:
                   it additionally serves \"lsh_query\" over its
                   bucket-prefix range of a deterministic demo point set
                   (--points 4096, --tables 8, --prefix-bits 12,
                    --fleet-seed 71 — must match on every shard)
                  TS_FAULT=panic:p,err:p,delay_ms:d,conn_drop:p,
                  slow_read_ms:d,partial_write:p,down_after_ms:t,
                  down_for_ms:d,seed:s injects deterministic backend +
                  transport faults incl. a whole-shard kill window
  route           shard-router front-end: --tcp ADDR --shards
                  \"host:p|replica,host:p,...\" (commas = shard groups,
                  pipes = replicas). Routes compute ops to their
                  rendezvous-hash owner with replica failover; fans
                  \"lsh_query\" out to every group (hedged stragglers)
                  and merges top-k, degrading missing shards to a
                  \"partial\" reply. Knobs: --attempt-timeout-ms 2000,
                  --scatter-budget-ms 3000, --probe-interval-ms 100,
                  --probe-timeout-ms 250, --breaker-threshold 3,
                  --breaker-cooldown-ms 250, --hedge-min-ms 1,
                  --hedge-max-ms 100, --hedge-initial-ms 10,
                  --max-conns 256, --drain-deadline-ms 5000
  transform       one-shot transform (--family hd3|hdg|circulant|toeplitz|
                  hankel|skew|dense, --n 256, --seed 42; --binary adds the
                  packed sign-quantized embedding + footprint accounting)
  metrics-demo    short native-backend burst, dumps metrics JSON
"
    );
}

fn parse_opts(args: &[String]) -> HashMap<String, String> {
    let mut m = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            match args.get(i + 1) {
                Some(v) if !v.starts_with("--") => {
                    m.insert(key.to_string(), v.clone());
                    i += 2;
                }
                _ => {
                    m.insert(key.to_string(), "true".into());
                    i += 1;
                }
            }
        } else {
            i += 1;
        }
    }
    m
}

fn opt<T: std::str::FromStr>(opts: &HashMap<String, String>, key: &str, default: T) -> T {
    opts.get(key)
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn artifact_dir(opts: &HashMap<String, String>) -> PathBuf {
    PathBuf::from(
        opts.get("artifacts")
            .cloned()
            .unwrap_or_else(|| "artifacts".into()),
    )
}

fn cmd_info(opts: &HashMap<String, String>) -> i32 {
    let dir = artifact_dir(opts);
    match triplespin::runtime::Manifest::load(&dir) {
        Ok(m) => {
            println!("artifact dir: {}", dir.display());
            println!(
                "{:<28} {:>6} {:>6} {:>12} {:>8}",
                "name", "n", "batch", "output", "golden"
            );
            for a in &m.artifacts {
                println!(
                    "{:<28} {:>6} {:>6} {:>12} {:>8}",
                    a.name,
                    a.n,
                    a.batch,
                    format!("{:?}", a.output),
                    a.golden.is_some()
                );
            }
            println!("\nlanes: {:?}", m.lanes());
            0
        }
        Err(e) => {
            eprintln!("{e}\nhint: run `make artifacts` first");
            1
        }
    }
}

fn cmd_verify(opts: &HashMap<String, String>) -> i32 {
    let dir = artifact_dir(opts);
    let svc = match RuntimeService::spawn(dir) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let h = svc.handle();
    let mut failures = 0;
    for name in h.names().unwrap_or_default() {
        match h.verify_golden(&name) {
            Ok(Some((err, numel))) => {
                let ok = err < 2e-3;
                println!(
                    "{:<28} max|err| = {err:.3e} over {numel} elements  {}",
                    name,
                    if ok { "OK" } else { "FAIL" }
                );
                if !ok {
                    failures += 1;
                }
            }
            Ok(None) => println!("{name:<28} (no golden vectors)"),
            Err(e) => {
                println!("{name:<28} ERROR: {e}");
                failures += 1;
            }
        }
    }
    svc.shutdown();
    if failures > 0 {
        eprintln!("{failures} artifact(s) failed verification");
        1
    } else {
        0
    }
}

fn cmd_transform(opts: &HashMap<String, String>) -> i32 {
    let n: usize = opt(opts, "n", 256);
    let seed: u64 = opt(opts, "seed", 42);
    let fam_s = opts.get("family").cloned().unwrap_or_else(|| "hd3".into());
    let Some(family) = Family::parse(&fam_s) else {
        eprintln!("unknown family '{fam_s}'");
        return 2;
    };
    if !n.is_power_of_two() && family != Family::Dense {
        eprintln!("n must be a power of two for Hadamard-based families");
        return 2;
    }
    let mut rng = Rng::new(seed);
    let t = make_square(family, n, &mut rng);
    let x = Rng::new(seed ^ 0xABCD).unit_vec(n);
    let start = Instant::now();
    let y = t.apply(&x);
    let dt = start.elapsed();
    let norm: f64 = y.iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt();
    println!("family   : {} ({})", family.name(), family.label());
    println!("n        : {n}");
    println!(
        "params   : {} bits ({:.1} KiB)",
        t.param_bits(),
        t.param_bits() as f64 / 8192.0
    );
    println!(
        "stored   : {} bits ({:.1} KiB actual in-memory parameter footprint)",
        t.stored_bits(),
        t.stored_bits() as f64 / 8192.0
    );
    println!("apply    : {dt:?}");
    println!(
        "||y||/√n : {:.4} (≈1 for Gaussian-like rows)",
        norm / (n as f64).sqrt()
    );
    println!("y[..8]   : {:?}", &y[..8.min(n)]);
    if opts.contains_key("binary") {
        // the bit-matrix serving story: sign-quantize the same transform's
        // output and account for the end-to-end bit footprint
        let mut rng2 = Rng::new(seed);
        let emb = triplespin::binary::BinaryEmbedding::with_family(family, n, &mut rng2);
        let code = emb.embed(&x);
        for (i, yi) in y.iter().enumerate() {
            assert_eq!(code.get(i), yi.is_sign_negative(), "embed contract bit {i}");
        }
        println!("binary   : {} code bits ({} B packed words)", code.bits(), code.storage_bytes());
        println!(
            "output   : {} bits/embedding vs {} bits f32 (32x smaller responses)",
            emb.output_bits(),
            32 * n
        );
        println!(
            "code[..4]: {:?}",
            code.words()
                .iter()
                .take(4)
                .map(|w| format!("{w:016x}"))
                .collect::<Vec<_>>()
        );
    }
    0
}

fn build_coordinator(
    opts: &HashMap<String, String>,
    lanes: Vec<(Op, usize)>,
) -> Result<(Coordinator, Option<RuntimeService>), String> {
    let sigma: f64 = opt(opts, "sigma", 1.0);
    let seed: u64 = opt(opts, "seed", 42);
    let dims: Vec<usize> = {
        let mut d: Vec<usize> = lanes.iter().map(|(_, n)| *n).collect();
        d.sort_unstable();
        d.dedup();
        d
    };
    let deadline_ms: u64 = opt(opts, "deadline-ms", 0);
    // --max-wait-ms is the coarse (ingress-friendly) alternative to
    // --max-wait-us; when both are given the millisecond knob wins
    let max_wait = if opts.contains_key("max-wait-ms") {
        Duration::from_millis(opt(opts, "max-wait-ms", 0))
    } else {
        Duration::from_micros(opt(opts, "max-wait-us", 200))
    };
    let config = Config {
        lanes,
        max_batch: opt(opts, "max-batch", 64),
        max_wait,
        queue_cap: opt(opts, "queue", 1024),
        sigma,
        seed,
        deadline: (deadline_ms > 0).then(|| Duration::from_millis(deadline_ms)),
        breaker_threshold: opt(opts, "breaker-threshold", 8),
        breaker_cooldown: Duration::from_millis(opt(opts, "breaker-cooldown-ms", 250)),
        // overload protection: per-client token bucket in work units
        // (0 = admission off) and queue-delay shedder (0 = shedding off)
        admission_rate: opt(opts, "admit-rate", 0.0),
        admission_burst: opt(opts, "admit-burst", 0.0),
        shed_target: Duration::from_millis(opt(opts, "shed-target-ms", 0)),
        shed_window: Duration::from_millis(opt(opts, "shed-window-ms", 100)),
        // cost-model flush bound: a lane batch closes early once it holds
        // this much estimated work, so one huge row never waits on stragglers
        flush_work: opt(opts, "flush-work", 0),
        ..Config::default()
    };
    let backend_s = opts
        .get("backend")
        .cloned()
        .unwrap_or_else(|| "native".into());
    let (be, svc): (Arc<dyn Backend>, Option<RuntimeService>) = match backend_s.as_str() {
        "native" => (Arc::new(NativeBackend::new(&dims, sigma, seed)), None),
        "pjrt" => {
            let svc = RuntimeService::spawn(artifact_dir(opts)).map_err(|e| e.to_string())?;
            let be: Arc<dyn Backend> =
                Arc::new(PjrtBackend::new(svc.handle(), &dims, sigma, seed)?);
            (be, Some(svc))
        }
        other => return Err(format!("unknown backend '{other}' (native|pjrt)")),
    };
    // chaos testing: TS_FAULT wraps whichever backend was selected; a
    // malformed plan aborts startup rather than silently injecting nothing
    let be = FaultInjectingBackend::wrap_env(be)?;
    if be.name() == "fault" {
        let plan = std::env::var("TS_FAULT").unwrap_or_default();
        eprintln!("TS_FAULT active: injecting backend faults ({plan})");
    }
    Ok((Coordinator::start(config, be), svc))
}

fn cmd_serve(opts: &HashMap<String, String>) -> i32 {
    let n: usize = opt(opts, "n", 256);
    // binary_embed is native-only: the PJRT artifact set has no packed-bit
    // op, so requests on that lane would all fail at runtime
    let is_pjrt = opts.get("backend").map(String::as_str) == Some("pjrt");
    // --tcp <addr>: serve the newline-JSON protocol instead of the
    // built-in load driver. E.g. `triplespin serve --tcp 127.0.0.1:7878`.
    if let Some(addr) = opts.get("tcp") {
        let mut lanes = vec![(Op::Transform, n), (Op::Rff, n), (Op::CrossPolytope, n)];
        if !is_pjrt {
            lanes.push((Op::BinaryEmbed, n));
        }
        let (c, _svc) = match build_coordinator(opts, lanes) {
            Ok(v) => v,
            Err(e) => {
                eprintln!("{e}");
                return 1;
            }
        };
        let c = Arc::new(c);
        // transport-fault keys of TS_FAULT (conn_drop/slow_read_ms/
        // partial_write) are applied by the TCP server, not the backend
        // wrapper; a malformed plan already aborted in build_coordinator
        let net_faults = triplespin::coordinator::FaultPlan::from_env()
            .ok()
            .flatten()
            .filter(|p| p.has_net_faults())
            .unwrap_or_default();
        if net_faults.has_net_faults() {
            eprintln!("TS_FAULT active: injecting transport faults");
        }
        let server_opts = triplespin::coordinator::ServerOptions {
            max_conns: opt(opts, "max-conns", 256),
            drain_deadline: Duration::from_millis(opt(opts, "drain-deadline-ms", 5000)),
            net_faults,
        };
        // --shard I/N: serve as one fleet shard — same wire protocol plus
        // `lsh_query` over this node's bucket-prefix range of the shared
        // demo point set (every shard must use identical index knobs)
        let mut shard_banner = String::new();
        let service: Arc<dyn triplespin::coordinator::LineService> =
            if let Some(spec) = opts.get("shard") {
                let Some((shard, shards)) = parse_shard_spec(spec) else {
                    eprintln!("--shard wants I/N with I < N (e.g. --shard 0/3), got '{spec}'");
                    return 2;
                };
                let points: usize = opt(opts, "points", 4096);
                let cfg = triplespin::router::ShardIndexConfig {
                    n,
                    tables: opt(opts, "tables", 8),
                    prefix_bits: opt(opts, "prefix-bits", 12),
                    seed: opt(opts, "fleet-seed", 71),
                    shard,
                    shards,
                };
                let index = triplespin::router::ShardIndex::build(
                    &triplespin::router::demo_points(n, points, cfg.seed),
                    &cfg,
                );
                shard_banner = format!(
                    "shard {shard}/{shards}: serving lsh_query over {} of {points} demo points\n ",
                    index.len()
                );
                Arc::new(triplespin::router::ShardService::new(Arc::clone(&c), index))
            } else {
                // coalescing ingress: in-flight dedup + bounded response
                // cache in front of the coordinator (--cache-cap 0 and
                // --no-dedup turn the pieces off individually)
                let ingress = triplespin::coordinator::IngressOptions {
                    cache_cap: opt(opts, "cache-cap", 256),
                    dedup: !opts.contains_key("no-dedup"),
                };
                Arc::new(triplespin::coordinator::CoordinatorService::with_ingress(
                    Arc::clone(&c),
                    ingress,
                ))
            };
        let server =
            match triplespin::coordinator::server::serve(Arc::clone(&service), addr, server_opts) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("bind {addr}: {e}");
                    return 1;
                }
            };
        let ops = if is_pjrt {
            "transform/rff/crosspolytope"
        } else {
            "transform/rff/crosspolytope/binary_embed"
        };
        println!(
            "{shard_banner}listening on {} (ops: {ops}, n={n}, max_conns={});\n\
             protocol: one JSON per line: {{\"id\":1,\"op\":\"transform\",\"vector\":[..]}}\n\
             optional per request: \"timeout_ms\", \"client_id\" (admission key),\n\
             \"priority\" 0-2, \"no_cache\" true opts out of the response cache;\n\
             identical concurrent requests are deduplicated (one computes, the\n\
             rest share the reply); ops \"metrics\", \"health\", \"metrics_text\" report\n\
             per-lane counters / breaker state / drain state; errors carry a \"code\"\n\
             (busy|deadline|unavailable|lane_down|backend|panic|timeout|bad_request\n\
             |throttled|overloaded|draining|shard_down) and retryable ones a\n\
             \"retry_after_ms\"; degraded fleet answers carry code \"partial\"\n\
             (binary_embed results are packed sign words as 16-digit hex strings)\n\
             SIGTERM/Ctrl-C drains gracefully.",
            server.addr(),
            server_opts.max_conns,
        );
        // block until SIGTERM/SIGINT, then drain instead of dying
        // mid-request: refuse new work with `draining` + retry hint, let
        // in-flight work finish under the drain deadline, then join
        let latch = triplespin::util::signal::termination_latch();
        // ORDERING: Relaxed — one-way latch polled in a loop; the signal
        // handler publishes nothing else.
        while !latch.load(std::sync::atomic::Ordering::Relaxed) {
            std::thread::sleep(Duration::from_millis(100));
        }
        eprintln!("termination signal: draining (deadline {:?})", server_opts.drain_deadline);
        let clean = server.shutdown_graceful();
        drop(service); // releases the service's coordinator handle
        match Arc::try_unwrap(c) {
            Ok(c) => c.shutdown(),
            Err(_) => eprintln!("coordinator still referenced at exit; skipping join"),
        }
        if clean {
            eprintln!("drained cleanly: all in-flight work completed");
            return 0;
        }
        eprintln!("drain deadline hit: queued work answered with code \"deadline\"");
        return 0;
    }
    let requests: usize = opt(opts, "requests", 2000);
    let rate: f64 = opt(opts, "rate", 0.0); // 0 = as fast as possible
    let op_s = opts
        .get("op")
        .cloned()
        .unwrap_or_else(|| "transform".into());
    let Some(op) = Op::parse(&op_s) else {
        eprintln!("unknown op '{op_s}'");
        return 2;
    };
    if is_pjrt && op == Op::BinaryEmbed {
        eprintln!("binary_embed is native-only (no PJRT artifact); use --backend native");
        return 2;
    }
    let (c, svc) = match build_coordinator(opts, vec![(op, n)]) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    println!(
        "serving {requests} {op} requests (n={n}, backend={})...",
        opts.get("backend").map(String::as_str).unwrap_or("native")
    );

    let mut rng = Rng::new(7);
    let start = Instant::now();
    let mut pending = Vec::new();
    let mut rejected = 0usize;
    let gap = if rate > 0.0 {
        Duration::from_secs_f64(1.0 / rate)
    } else {
        Duration::ZERO
    };
    for i in 0..requests {
        loop {
            match c.submit(op, rng.gaussian_vec(n)) {
                Ok(p) => {
                    pending.push(p);
                    break;
                }
                Err(triplespin::coordinator::SubmitError::Busy) => {
                    rejected += 1;
                    // drain one response then retry (simple client-side flow control)
                    if let Some((_, rx)) = pending.pop() {
                        let _ = rx.recv();
                    }
                }
                Err(e) => {
                    eprintln!("submit failed: {e}");
                    return 1;
                }
            }
        }
        if !gap.is_zero() && i % 16 == 0 {
            std::thread::sleep(gap * 16);
        }
    }
    for (_, rx) in pending {
        if rx.recv().map(|r| r.result.is_err()).unwrap_or(true) {
            eprintln!("a request failed");
        }
    }
    let dt = start.elapsed();
    println!(
        "done: {requests} requests in {dt:?}  ({:.0} req/s, {rejected} Busy signals)",
        requests as f64 / dt.as_secs_f64()
    );
    println!("metrics: {}", c.metrics_json());
    c.shutdown();
    if let Some(s) = svc {
        s.shutdown();
    }
    0
}

/// Parse `--shard I/N` (shard index / fleet width).
fn parse_shard_spec(spec: &str) -> Option<(usize, usize)> {
    let (i, m) = spec.split_once('/')?;
    let (i, m) = (i.trim().parse().ok()?, m.trim().parse().ok()?);
    (m >= 1 && i < m).then_some((i, m))
}

/// `route`: the fleet front-end — no backend of its own, just the shard
/// topology and the routing/hedging/failover policies.
fn cmd_route(opts: &HashMap<String, String>) -> i32 {
    let Some(addr) = opts.get("tcp") else {
        eprintln!("route needs --tcp ADDR to listen on");
        return 2;
    };
    let Some(spec) = opts.get("shards") else {
        eprintln!("route needs --shards \"host:p|replica,host:p,...\"");
        return 2;
    };
    let specs = match triplespin::router::parse_topology(spec) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let ropts = triplespin::router::RouterOptions {
        attempt_timeout: Duration::from_millis(opt(opts, "attempt-timeout-ms", 2000)),
        scatter_budget: Duration::from_millis(opt(opts, "scatter-budget-ms", 3000)),
        probe_interval: Duration::from_millis(opt(opts, "probe-interval-ms", 100)),
        probe_timeout: Duration::from_millis(opt(opts, "probe-timeout-ms", 250)),
        breaker_threshold: opt(opts, "breaker-threshold", 3),
        breaker_cooldown: Duration::from_millis(opt(opts, "breaker-cooldown-ms", 250)),
        hedge_min: Duration::from_millis(opt(opts, "hedge-min-ms", 1)),
        hedge_max: Duration::from_millis(opt(opts, "hedge-max-ms", 100)),
        hedge_initial: Duration::from_millis(opt(opts, "hedge-initial-ms", 10)),
    };
    let groups = specs.len();
    let replicas: usize = specs.iter().map(|s| s.endpoints.len()).sum();
    let router = Arc::new(triplespin::router::ShardRouter::new(specs, ropts));
    let server_opts = triplespin::coordinator::ServerOptions {
        max_conns: opt(opts, "max-conns", 256),
        drain_deadline: Duration::from_millis(opt(opts, "drain-deadline-ms", 5000)),
        net_faults: Default::default(),
    };
    let server = match triplespin::coordinator::server::serve(router, addr, server_opts) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("bind {addr}: {e}");
            return 1;
        }
    };
    println!(
        "routing on {} over {groups} shard group(s), {replicas} replica(s);\n\
         compute ops go to their rendezvous owner (failover through replicas\n\
         and fallback groups); \"lsh_query\" scatter-gathers every group and\n\
         marks missing shards in a \"partial\" reply; \"metrics\" / \"health\" /\n\
         \"metrics_text\" report fleet counters and per-endpoint breaker state.\n\
         SIGTERM/Ctrl-C drains gracefully.",
        server.addr(),
    );
    let latch = triplespin::util::signal::termination_latch();
    // ORDERING: Relaxed — one-way latch polled in a loop; the signal
    // handler publishes nothing else.
    while !latch.load(std::sync::atomic::Ordering::Relaxed) {
        std::thread::sleep(Duration::from_millis(100));
    }
    eprintln!("termination signal: draining (deadline {:?})", server_opts.drain_deadline);
    server.shutdown_graceful();
    0
}

fn cmd_metrics_demo(opts: &HashMap<String, String>) -> i32 {
    let mut o = opts.clone();
    o.entry("requests".into()).or_insert("500".into());
    cmd_serve(&o)
}
