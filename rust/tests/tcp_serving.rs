//! Serving smoke: the TCP layer end to end on an ephemeral port.
//!
//! CI runs this as a named step (`cargo test --test tcp_serving`): start a
//! real `TcpServer`, round-trip one `transform` and one `binary_embed`
//! request over a socket, decode the packed hex words against the float
//! lane, and force the bounded lane queue over capacity so backpressure
//! provably surfaces as `ok:false / "lane queue full"` on the wire. The
//! overload-protection contracts are pinned here too: graceful drain
//! (in-flight completes, new work gets `draining` + `retry_after_ms`,
//! shutdown joins within the drain deadline) and the `--max-conns`
//! accept-loop cap (`overloaded` one-line refusals under a flood).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Barrier, Mutex};
use std::time::{Duration, Instant};

use triplespin::coordinator::{
    server::hex_to_word, server::serve, Backend, Config, Coordinator, CoordinatorService,
    IngressOptions, LineService, NativeBackend, ServerOptions, TcpServer,
};
use triplespin::runtime::{Op, Output};
use triplespin::util::json::Json;

const N: usize = 64;

fn config(queue_cap: usize, max_wait: Duration) -> Config {
    Config {
        lanes: vec![(Op::Transform, N), (Op::BinaryEmbed, N)],
        max_batch: 1,
        max_wait,
        queue_cap,
        sigma: 1.0,
        seed: 17,
        ..Config::default()
    }
}

fn vector_json() -> String {
    let vals: Vec<String> = (0..N).map(|i| format!("{}", i as f32 / 8.0 - 4.0)).collect();
    vals.join(",")
}

fn request(stream: &mut TcpStream, reader: &mut BufReader<TcpStream>, id: u64, op: &str) -> Json {
    let line = format!("{{\"id\": {id}, \"op\": \"{op}\", \"vector\": [{}]}}\n", vector_json());
    stream.write_all(line.as_bytes()).unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    Json::parse(resp.trim()).unwrap()
}

#[test]
fn round_trip_transform_and_binary_embed() {
    let backend = Arc::new(NativeBackend::new(&[N], 1.0, 17));
    let c = Arc::new(Coordinator::start(
        config(64, Duration::from_micros(200)),
        backend,
    ));
    let server = TcpServer::start(Arc::clone(&c), "127.0.0.1:0").unwrap();
    let addr = server.addr();

    let mut stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());

    let t = request(&mut stream, &mut reader, 1, "transform");
    assert_eq!(t.get("ok"), Some(&Json::Bool(true)), "{t}");
    let dense = t.get("result").unwrap().as_arr().unwrap();
    assert_eq!(dense.len(), N);

    let b = request(&mut stream, &mut reader, 2, "binary_embed");
    assert_eq!(b.get("ok"), Some(&Json::Bool(true)), "{b}");
    let words = b.get("result").unwrap().as_arr().unwrap();
    assert_eq!(words.len(), N.div_ceil(64), "one packed word per 64 bits");
    let word = hex_to_word(words[0].as_str().unwrap()).expect("fixed-width hex");
    // the hex code must be the sign pattern of the float lane's response
    for (i, y) in dense.iter().enumerate() {
        let neg = y.as_f64().unwrap().is_sign_negative();
        assert_eq!((word >> i) & 1 == 1, neg, "bit {i}");
    }

    drop(reader);
    drop(stream);
    server.shutdown();
}

#[test]
fn metrics_and_health_ops_round_trip_on_the_wire() {
    let backend = Arc::new(NativeBackend::new(&[N], 1.0, 17));
    let c = Arc::new(Coordinator::start(
        config(64, Duration::from_micros(200)),
        backend,
    ));
    let server = TcpServer::start(Arc::clone(&c), "127.0.0.1:0").unwrap();
    let addr = server.addr();

    let mut stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());

    // serve two real requests so the counters have something to say
    for id in 1..=2 {
        let t = request(&mut stream, &mut reader, id, "transform");
        assert_eq!(t.get("ok"), Some(&Json::Bool(true)), "{t}");
    }
    // metrics op: per-lane counters, including the fault-isolation schema
    stream.write_all(b"{\"id\": 10, \"op\": \"metrics\"}\n").unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    let m = Json::parse(resp.trim()).unwrap();
    assert_eq!(m.get("ok"), Some(&Json::Bool(true)), "{m}");
    let lane = m
        .get("result")
        .and_then(|r| r.get(&format!("transform_n{N}")))
        .expect("transform lane in metrics");
    assert_eq!(lane.get("completed").unwrap().as_f64(), Some(2.0));
    for key in ["lane_failures", "restarts", "breaker_opens", "expired", "panics"] {
        assert_eq!(lane.get(key).unwrap().as_f64(), Some(0.0), "{key}");
    }
    // health op: every lane open on a healthy server
    stream.write_all(b"{\"id\": 11, \"op\": \"health\"}\n").unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    let h = Json::parse(resp.trim()).unwrap();
    assert_eq!(h.get("ok"), Some(&Json::Bool(true)), "{h}");
    for op in ["transform", "binary_embed"] {
        let lane = h
            .get("result")
            .and_then(|r| r.get(&format!("{op}_n{N}")))
            .expect("lane in health");
        assert_eq!(lane.get("state").unwrap().as_str(), Some("open"), "{op}");
    }

    drop(reader);
    drop(stream);
    server.shutdown();
}

/// Backend wrapper that stalls each batch long enough for the test to fill
/// the lane queue behind it.
struct SlowBackend {
    inner: NativeBackend,
    delay: Duration,
}

impl Backend for SlowBackend {
    fn run_batch(&self, op: Op, n: usize, rows: usize, xs: &[f32]) -> Result<Output, String> {
        std::thread::sleep(self.delay);
        self.inner.run_batch(op, n, rows, xs)
    }
    fn name(&self) -> &'static str {
        "slow"
    }
}

#[test]
fn backpressure_surfaces_as_ok_false_on_the_wire() {
    // queue_cap 1 + a 300ms backend: the first request occupies the
    // backend, the second fills the queue, later arrivals MUST be shed
    // with ok:false "lane queue full" — the load-shedding contract.
    let backend = Arc::new(SlowBackend {
        inner: NativeBackend::new(&[N], 1.0, 17),
        delay: Duration::from_millis(300),
    });
    let c = Arc::new(Coordinator::start(
        config(1, Duration::from_micros(50)),
        backend,
    ));
    let server = TcpServer::start(Arc::clone(&c), "127.0.0.1:0").unwrap();
    let addr = server.addr();

    let mut joins = Vec::new();
    for t in 0..6u64 {
        joins.push(std::thread::spawn(move || {
            let mut stream = TcpStream::connect(addr).unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let doc = request(&mut stream, &mut reader, t, "binary_embed");
            match doc.get("ok") {
                Some(&Json::Bool(true)) => (true, String::new()),
                _ => (
                    false,
                    doc.get("error")
                        .and_then(|e| e.as_str())
                        .unwrap_or_default()
                        .to_string(),
                ),
            }
        }));
    }
    let results: Vec<(bool, String)> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    let ok = results.iter().filter(|(s, _)| *s).count();
    let shed: Vec<&String> = results.iter().filter(|(s, _)| !*s).map(|(_, e)| e).collect();
    assert!(ok >= 1, "at least one request must be served: {results:?}");
    assert!(
        !shed.is_empty(),
        "6 concurrent requests against a cap-1 queue + 300ms backend must shed load"
    );
    for e in &shed {
        assert_eq!(e.as_str(), "lane queue full", "shed requests must cite backpressure");
    }
    server.shutdown();
}

#[test]
fn graceful_drain_completes_in_flight_refuses_new_and_joins() {
    // a 200ms backend so one request is mid-backend when drain begins:
    // it must still complete, while everything arriving after the drain
    // latch gets a typed `draining` refusal with a retry hint
    let backend = Arc::new(SlowBackend {
        inner: NativeBackend::new(&[N], 1.0, 17),
        delay: Duration::from_millis(200),
    });
    let c = Arc::new(Coordinator::start(
        config(8, Duration::from_micros(50)),
        backend,
    ));
    let opts = ServerOptions {
        drain_deadline: Duration::from_secs(5),
        ..Default::default()
    };
    let server = TcpServer::start_with(Arc::clone(&c), "127.0.0.1:0", opts).unwrap();
    let addr = server.addr();

    // in-flight request: submitted before drain, answered during it
    let inflight = std::thread::spawn(move || {
        let mut stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        request(&mut stream, &mut reader, 1, "transform")
    });
    // a second pre-drain connection, held open across the drain latch —
    // a metrics round-trip (backend-free) proves its handler is attached
    // before the drain flips, closing the accept-race window
    let mut held = TcpStream::connect(addr).unwrap();
    let mut held_reader = BufReader::new(held.try_clone().unwrap());
    held.write_all(b"{\"id\": 0, \"op\": \"metrics\"}\n").unwrap();
    let mut ml = String::new();
    held_reader.read_line(&mut ml).unwrap();
    assert_eq!(
        Json::parse(ml.trim()).unwrap().get("ok"),
        Some(&Json::Bool(true))
    );
    // let the in-flight request reach the backend before draining
    std::thread::sleep(Duration::from_millis(80));

    server.begin_drain();

    // new connection after drain: one-line accept-loop refusal
    {
        let refused = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(refused);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let doc = Json::parse(line.trim()).unwrap();
        assert_eq!(doc.get("ok"), Some(&Json::Bool(false)), "{doc}");
        assert_eq!(doc.get("code").unwrap().as_str(), Some("draining"));
        assert!(doc.get("retry_after_ms").unwrap().as_f64().unwrap() > 0.0);
    }

    // new request on the surviving pre-drain connection: coordinator-level
    // refusal, same code and hint
    let r = request(&mut held, &mut held_reader, 2, "transform");
    assert_eq!(r.get("ok"), Some(&Json::Bool(false)), "{r}");
    assert_eq!(r.get("code").unwrap().as_str(), Some("draining"));
    assert!(r.get("retry_after_ms").unwrap().as_f64().unwrap() > 0.0);

    // the in-flight request was admitted before drain — it must complete
    let a = inflight.join().unwrap();
    assert_eq!(a.get("ok"), Some(&Json::Bool(true)), "{a}");

    drop(held_reader);
    drop(held);
    // graceful shutdown: nothing queued is left, so the drain reports
    // clean and the join completes well inside the deadline
    let start = Instant::now();
    assert!(
        server.shutdown_graceful(),
        "no queued work should hit the drain cutoff"
    );
    assert!(
        start.elapsed() < Duration::from_secs(5),
        "drain must not consume the full deadline when idle"
    );
}

/// Backend wrapper that counts `run_batch` calls and records each call's
/// row count — the ground truth for "the backend ran exactly once" in the
/// dedup tests and for coalesced-batch-size evidence.
struct CountingBackend {
    inner: NativeBackend,
    delay: Duration,
    calls: AtomicU64,
    batch_rows: Mutex<Vec<usize>>,
}

impl CountingBackend {
    fn new(delay: Duration) -> Self {
        CountingBackend {
            inner: NativeBackend::new(&[N], 1.0, 17),
            delay,
            calls: AtomicU64::new(0),
            batch_rows: Mutex::new(Vec::new()),
        }
    }
}

impl Backend for CountingBackend {
    fn run_batch(&self, op: Op, n: usize, rows: usize, xs: &[f32]) -> Result<Output, String> {
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.batch_rows.lock().unwrap().push(rows);
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        self.inner.run_batch(op, n, rows, xs)
    }
    fn name(&self) -> &'static str {
        "counting"
    }
}

/// Start an ingress-fronted server (dedup + response cache in front of the
/// coordinator) over the given backend.
fn serve_with_ingress(
    backend: Arc<dyn Backend>,
    cfg: Config,
    ingress: IngressOptions,
) -> (Arc<Coordinator>, TcpServer) {
    let c = Arc::new(Coordinator::start(cfg, backend));
    let service: Arc<dyn LineService> =
        Arc::new(CoordinatorService::with_ingress(Arc::clone(&c), ingress));
    let server = serve(service, "127.0.0.1:0", ServerOptions::default()).unwrap();
    (c, server)
}

#[test]
fn batch_dedup_leader_computes_once_and_fans_out() {
    // 8 concurrent byte-identical requests against a 400ms backend: exactly
    // one backend call computes, every client gets the same bytes back, and
    // everyone who didn't lead is accounted for as a follower or cache hit.
    let backend = Arc::new(CountingBackend::new(Duration::from_millis(400)));
    let (c, server) = serve_with_ingress(
        Arc::clone(&backend) as Arc<dyn Backend>,
        config(64, Duration::from_micros(200)),
        IngressOptions::default(),
    );
    let addr = server.addr();

    let clients = 8usize;
    let barrier = Arc::new(Barrier::new(clients));
    let mut joins = Vec::new();
    for _ in 0..clients {
        let barrier = Arc::clone(&barrier);
        joins.push(std::thread::spawn(move || {
            // same id on purpose: identical requests must yield identical
            // reply bytes, id included
            let line =
                format!("{{\"id\": 7, \"op\": \"transform\", \"vector\": [{}]}}\n", vector_json());
            let mut stream = TcpStream::connect(addr).unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            barrier.wait();
            stream.write_all(line.as_bytes()).unwrap();
            let mut resp = String::new();
            reader.read_line(&mut resp).unwrap();
            resp
        }));
    }
    let replies: Vec<String> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    for r in &replies {
        let doc = Json::parse(r.trim()).unwrap();
        assert_eq!(doc.get("ok"), Some(&Json::Bool(true)), "{doc}");
        assert_eq!(r, &replies[0], "dedup fan-out must be byte-identical");
    }
    assert_eq!(
        backend.calls.load(Ordering::Relaxed),
        1,
        "one leader computes; followers subscribe to its slot"
    );
    let m = c.lane_metrics(Op::Transform, N).expect("transform lane metrics");
    let followers = m.dedup_followers.load(Ordering::Relaxed);
    let hits = m.cache_hits.load(Ordering::Relaxed);
    assert_eq!(
        followers + hits,
        (clients - 1) as u64,
        "everyone but the leader is a dedup follower (or a late cache hit)"
    );
    assert!(followers >= 1, "a 400ms compute window must catch followers in flight");
    server.shutdown();
    drop(c);
}

#[test]
fn batch_cache_hits_skip_backend_and_no_cache_opts_out() {
    let backend = Arc::new(CountingBackend::new(Duration::ZERO));
    let (c, server) = serve_with_ingress(
        Arc::clone(&backend) as Arc<dyn Backend>,
        config(64, Duration::from_micros(200)),
        IngressOptions::default(),
    );
    let addr = server.addr();
    let mut stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());

    let line = format!("{{\"id\": 3, \"op\": \"transform\", \"vector\": [{}]}}\n", vector_json());
    let mut send = |line: &str| {
        stream.write_all(line.as_bytes()).unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        resp
    };
    let first = send(&line);
    assert_eq!(
        Json::parse(first.trim()).unwrap().get("ok"),
        Some(&Json::Bool(true)),
        "{first}"
    );
    let calls_after_first = backend.calls.load(Ordering::Relaxed);

    // exact repeat: answered from the response cache, byte-identical,
    // zero additional backend time
    let second = send(&line);
    assert_eq!(second, first, "cache hit must replay the same bytes");
    assert_eq!(
        backend.calls.load(Ordering::Relaxed),
        calls_after_first,
        "cache hits must not reach the backend"
    );

    // no_cache opts this request out: same reply payload, but recomputed
    let no_cache_line = format!(
        "{{\"id\": 3, \"op\": \"transform\", \"no_cache\": true, \"vector\": [{}]}}\n",
        vector_json()
    );
    let third = send(&no_cache_line);
    assert_eq!(third, first, "deterministic recompute matches the cached bytes");
    assert_eq!(
        backend.calls.load(Ordering::Relaxed),
        calls_after_first + 1,
        "no_cache must recompute"
    );

    let m = c.lane_metrics(Op::Transform, N).expect("transform lane metrics");
    assert_eq!(m.cache_hits.load(Ordering::Relaxed), 1);
    assert_eq!(m.cache_misses.load(Ordering::Relaxed), 1, "no_cache is not a miss");
    assert_eq!(m.cache_entries.load(Ordering::Relaxed), 1);

    // health reports cache occupancy on the wire
    let health = send("{\"id\": 4, \"op\": \"health\"}\n");
    let h = Json::parse(health.trim()).unwrap();
    let lane = h
        .get("result")
        .and_then(|r| r.get(&format!("transform_n{N}")))
        .expect("transform lane in health");
    assert_eq!(lane.get("cache_entries").unwrap().as_f64(), Some(1.0));

    drop(reader);
    drop(stream);
    server.shutdown();
    drop(c);
}

#[test]
fn batch_coalescing_evidence_under_concurrent_clients() {
    // The acceptance scenario: 32 concurrent single-row TCP clients with
    // DISTINCT vectors on one lane must coalesce into pooled batches with
    // mean batch size > 4, and every reply must be byte-identical to the
    // uncoalesced path.
    let backend = Arc::new(CountingBackend::new(Duration::from_millis(5)));
    let cfg = Config {
        max_batch: 32,
        max_wait: Duration::from_millis(100),
        ..config(256, Duration::from_millis(100))
    };
    let (c, server) = serve_with_ingress(
        Arc::clone(&backend) as Arc<dyn Backend>,
        cfg,
        IngressOptions::default(),
    );
    let addr = server.addr();

    // control: the same engine parameters with no ingress and no batching
    // (max_batch 1) — the uncoalesced baseline for byte-level comparison
    let control_c = Arc::new(Coordinator::start(
        config(256, Duration::from_micros(50)),
        Arc::new(NativeBackend::new(&[N], 1.0, 17)),
    ));
    let control = TcpServer::start(Arc::clone(&control_c), "127.0.0.1:0").unwrap();
    let control_addr = control.addr();

    let clients = 32usize;
    let barrier = Arc::new(Barrier::new(clients));
    let mut joins = Vec::new();
    for t in 0..clients {
        let barrier = Arc::clone(&barrier);
        joins.push(std::thread::spawn(move || {
            // distinct per-client vector: no dedup or cache sharing here,
            // coalescing alone must provide the batching
            let vals: Vec<String> = (0..N)
                .map(|i| format!("{}", (i + t * N) as f32 / 64.0 - 8.0))
                .collect();
            let line = format!(
                "{{\"id\": {t}, \"op\": \"transform\", \"vector\": [{}]}}\n",
                vals.join(",")
            );
            let mut stream = TcpStream::connect(addr).unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            barrier.wait();
            stream.write_all(line.as_bytes()).unwrap();
            let mut resp = String::new();
            reader.read_line(&mut resp).unwrap();

            // same request against the uncoalesced control server
            let mut cs = TcpStream::connect(control_addr).unwrap();
            let mut creader = BufReader::new(cs.try_clone().unwrap());
            cs.write_all(line.as_bytes()).unwrap();
            let mut control_resp = String::new();
            creader.read_line(&mut control_resp).unwrap();
            (resp, control_resp)
        }));
    }
    let pairs: Vec<(String, String)> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    for (resp, control_resp) in &pairs {
        let doc = Json::parse(resp.trim()).unwrap();
        assert_eq!(doc.get("ok"), Some(&Json::Bool(true)), "{doc}");
        assert_eq!(
            resp, control_resp,
            "coalesced reply must be byte-identical to the uncoalesced path"
        );
    }
    let sizes = backend.batch_rows.lock().unwrap().clone();
    let rows: usize = sizes.iter().sum();
    assert_eq!(rows, clients, "every admitted row reaches the backend exactly once");
    let mean = rows as f64 / sizes.len() as f64;
    assert!(
        mean > 4.0,
        "32 concurrent clients must coalesce (mean batch {mean:.2}, sizes {sizes:?})"
    );
    let m = c.lane_metrics(Op::Transform, N).expect("transform lane metrics");
    assert!(
        m.coalesced_rows.load(Ordering::Relaxed) > 0,
        "coalesced_rows must count rows served in multi-row batches"
    );
    control.shutdown();
    server.shutdown();
    drop(c);
}

#[test]
fn max_conns_flood_gets_coded_overloaded_refusals() {
    let backend = Arc::new(NativeBackend::new(&[N], 1.0, 17));
    let c = Arc::new(Coordinator::start(
        config(64, Duration::from_micros(200)),
        backend,
    ));
    let opts = ServerOptions {
        max_conns: 2,
        ..Default::default()
    };
    let server = TcpServer::start_with(Arc::clone(&c), "127.0.0.1:0", opts).unwrap();
    let addr = server.addr();

    // fill both slots with live connections (and prove they serve)
    let mut held = Vec::new();
    for id in 0..2u64 {
        let mut s = TcpStream::connect(addr).unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let doc = request(&mut s, &mut r, id, "transform");
        assert_eq!(doc.get("ok"), Some(&Json::Bool(true)), "{doc}");
        held.push((s, r));
    }
    // flood: every connection past the cap gets the one-line refusal
    for _ in 0..4 {
        let s = TcpStream::connect(addr).unwrap();
        let mut r = BufReader::new(s);
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        let doc = Json::parse(line.trim()).unwrap();
        assert_eq!(doc.get("ok"), Some(&Json::Bool(false)), "{doc}");
        assert_eq!(doc.get("code").unwrap().as_str(), Some("overloaded"));
        assert!(doc.get("retry_after_ms").unwrap().as_f64().unwrap() > 0.0);
    }
    // slots free up once the held connections close: a new connection is
    // admitted again (prune happens on the next accept)
    drop(held);
    std::thread::sleep(Duration::from_millis(250));
    let mut s = TcpStream::connect(addr).unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    let doc = request(&mut s, &mut r, 9, "transform");
    assert_eq!(doc.get("ok"), Some(&Json::Bool(true)), "{doc}");
    drop(r);
    drop(s);
    server.shutdown();
}
