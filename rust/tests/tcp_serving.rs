//! Serving smoke: the TCP layer end to end on an ephemeral port.
//!
//! CI runs this as a named step (`cargo test --test tcp_serving`): start a
//! real `TcpServer`, round-trip one `transform` and one `binary_embed`
//! request over a socket, decode the packed hex words against the float
//! lane, and force the bounded lane queue over capacity so backpressure
//! provably surfaces as `ok:false / "lane queue full"` on the wire. The
//! overload-protection contracts are pinned here too: graceful drain
//! (in-flight completes, new work gets `draining` + `retry_after_ms`,
//! shutdown joins within the drain deadline) and the `--max-conns`
//! accept-loop cap (`overloaded` one-line refusals under a flood).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use triplespin::coordinator::{
    server::hex_to_word, Backend, Config, Coordinator, NativeBackend, ServerOptions, TcpServer,
};
use triplespin::runtime::{Op, Output};
use triplespin::util::json::Json;

const N: usize = 64;

fn config(queue_cap: usize, max_wait: Duration) -> Config {
    Config {
        lanes: vec![(Op::Transform, N), (Op::BinaryEmbed, N)],
        max_batch: 1,
        max_wait,
        queue_cap,
        sigma: 1.0,
        seed: 17,
        ..Config::default()
    }
}

fn vector_json() -> String {
    let vals: Vec<String> = (0..N).map(|i| format!("{}", i as f32 / 8.0 - 4.0)).collect();
    vals.join(",")
}

fn request(stream: &mut TcpStream, reader: &mut BufReader<TcpStream>, id: u64, op: &str) -> Json {
    let line = format!("{{\"id\": {id}, \"op\": \"{op}\", \"vector\": [{}]}}\n", vector_json());
    stream.write_all(line.as_bytes()).unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    Json::parse(resp.trim()).unwrap()
}

#[test]
fn round_trip_transform_and_binary_embed() {
    let backend = Arc::new(NativeBackend::new(&[N], 1.0, 17));
    let c = Arc::new(Coordinator::start(
        config(64, Duration::from_micros(200)),
        backend,
    ));
    let server = TcpServer::start(Arc::clone(&c), "127.0.0.1:0").unwrap();
    let addr = server.addr();

    let mut stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());

    let t = request(&mut stream, &mut reader, 1, "transform");
    assert_eq!(t.get("ok"), Some(&Json::Bool(true)), "{t}");
    let dense = t.get("result").unwrap().as_arr().unwrap();
    assert_eq!(dense.len(), N);

    let b = request(&mut stream, &mut reader, 2, "binary_embed");
    assert_eq!(b.get("ok"), Some(&Json::Bool(true)), "{b}");
    let words = b.get("result").unwrap().as_arr().unwrap();
    assert_eq!(words.len(), N.div_ceil(64), "one packed word per 64 bits");
    let word = hex_to_word(words[0].as_str().unwrap()).expect("fixed-width hex");
    // the hex code must be the sign pattern of the float lane's response
    for (i, y) in dense.iter().enumerate() {
        let neg = y.as_f64().unwrap().is_sign_negative();
        assert_eq!((word >> i) & 1 == 1, neg, "bit {i}");
    }

    drop(reader);
    drop(stream);
    server.shutdown();
}

#[test]
fn metrics_and_health_ops_round_trip_on_the_wire() {
    let backend = Arc::new(NativeBackend::new(&[N], 1.0, 17));
    let c = Arc::new(Coordinator::start(
        config(64, Duration::from_micros(200)),
        backend,
    ));
    let server = TcpServer::start(Arc::clone(&c), "127.0.0.1:0").unwrap();
    let addr = server.addr();

    let mut stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());

    // serve two real requests so the counters have something to say
    for id in 1..=2 {
        let t = request(&mut stream, &mut reader, id, "transform");
        assert_eq!(t.get("ok"), Some(&Json::Bool(true)), "{t}");
    }
    // metrics op: per-lane counters, including the fault-isolation schema
    stream.write_all(b"{\"id\": 10, \"op\": \"metrics\"}\n").unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    let m = Json::parse(resp.trim()).unwrap();
    assert_eq!(m.get("ok"), Some(&Json::Bool(true)), "{m}");
    let lane = m
        .get("result")
        .and_then(|r| r.get(&format!("transform_n{N}")))
        .expect("transform lane in metrics");
    assert_eq!(lane.get("completed").unwrap().as_f64(), Some(2.0));
    for key in ["lane_failures", "restarts", "breaker_opens", "expired", "panics"] {
        assert_eq!(lane.get(key).unwrap().as_f64(), Some(0.0), "{key}");
    }
    // health op: every lane open on a healthy server
    stream.write_all(b"{\"id\": 11, \"op\": \"health\"}\n").unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    let h = Json::parse(resp.trim()).unwrap();
    assert_eq!(h.get("ok"), Some(&Json::Bool(true)), "{h}");
    for op in ["transform", "binary_embed"] {
        let lane = h
            .get("result")
            .and_then(|r| r.get(&format!("{op}_n{N}")))
            .expect("lane in health");
        assert_eq!(lane.get("state").unwrap().as_str(), Some("open"), "{op}");
    }

    drop(reader);
    drop(stream);
    server.shutdown();
}

/// Backend wrapper that stalls each batch long enough for the test to fill
/// the lane queue behind it.
struct SlowBackend {
    inner: NativeBackend,
    delay: Duration,
}

impl Backend for SlowBackend {
    fn run_batch(&self, op: Op, n: usize, rows: usize, xs: &[f32]) -> Result<Output, String> {
        std::thread::sleep(self.delay);
        self.inner.run_batch(op, n, rows, xs)
    }
    fn name(&self) -> &'static str {
        "slow"
    }
}

#[test]
fn backpressure_surfaces_as_ok_false_on_the_wire() {
    // queue_cap 1 + a 300ms backend: the first request occupies the
    // backend, the second fills the queue, later arrivals MUST be shed
    // with ok:false "lane queue full" — the load-shedding contract.
    let backend = Arc::new(SlowBackend {
        inner: NativeBackend::new(&[N], 1.0, 17),
        delay: Duration::from_millis(300),
    });
    let c = Arc::new(Coordinator::start(
        config(1, Duration::from_micros(50)),
        backend,
    ));
    let server = TcpServer::start(Arc::clone(&c), "127.0.0.1:0").unwrap();
    let addr = server.addr();

    let mut joins = Vec::new();
    for t in 0..6u64 {
        joins.push(std::thread::spawn(move || {
            let mut stream = TcpStream::connect(addr).unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let doc = request(&mut stream, &mut reader, t, "binary_embed");
            match doc.get("ok") {
                Some(&Json::Bool(true)) => (true, String::new()),
                _ => (
                    false,
                    doc.get("error")
                        .and_then(|e| e.as_str())
                        .unwrap_or_default()
                        .to_string(),
                ),
            }
        }));
    }
    let results: Vec<(bool, String)> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    let ok = results.iter().filter(|(s, _)| *s).count();
    let shed: Vec<&String> = results.iter().filter(|(s, _)| !*s).map(|(_, e)| e).collect();
    assert!(ok >= 1, "at least one request must be served: {results:?}");
    assert!(
        !shed.is_empty(),
        "6 concurrent requests against a cap-1 queue + 300ms backend must shed load"
    );
    for e in &shed {
        assert_eq!(e.as_str(), "lane queue full", "shed requests must cite backpressure");
    }
    server.shutdown();
}

#[test]
fn graceful_drain_completes_in_flight_refuses_new_and_joins() {
    // a 200ms backend so one request is mid-backend when drain begins:
    // it must still complete, while everything arriving after the drain
    // latch gets a typed `draining` refusal with a retry hint
    let backend = Arc::new(SlowBackend {
        inner: NativeBackend::new(&[N], 1.0, 17),
        delay: Duration::from_millis(200),
    });
    let c = Arc::new(Coordinator::start(
        config(8, Duration::from_micros(50)),
        backend,
    ));
    let opts = ServerOptions {
        drain_deadline: Duration::from_secs(5),
        ..Default::default()
    };
    let server = TcpServer::start_with(Arc::clone(&c), "127.0.0.1:0", opts).unwrap();
    let addr = server.addr();

    // in-flight request: submitted before drain, answered during it
    let inflight = std::thread::spawn(move || {
        let mut stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        request(&mut stream, &mut reader, 1, "transform")
    });
    // a second pre-drain connection, held open across the drain latch —
    // a metrics round-trip (backend-free) proves its handler is attached
    // before the drain flips, closing the accept-race window
    let mut held = TcpStream::connect(addr).unwrap();
    let mut held_reader = BufReader::new(held.try_clone().unwrap());
    held.write_all(b"{\"id\": 0, \"op\": \"metrics\"}\n").unwrap();
    let mut ml = String::new();
    held_reader.read_line(&mut ml).unwrap();
    assert_eq!(
        Json::parse(ml.trim()).unwrap().get("ok"),
        Some(&Json::Bool(true))
    );
    // let the in-flight request reach the backend before draining
    std::thread::sleep(Duration::from_millis(80));

    server.begin_drain();

    // new connection after drain: one-line accept-loop refusal
    {
        let refused = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(refused);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let doc = Json::parse(line.trim()).unwrap();
        assert_eq!(doc.get("ok"), Some(&Json::Bool(false)), "{doc}");
        assert_eq!(doc.get("code").unwrap().as_str(), Some("draining"));
        assert!(doc.get("retry_after_ms").unwrap().as_f64().unwrap() > 0.0);
    }

    // new request on the surviving pre-drain connection: coordinator-level
    // refusal, same code and hint
    let r = request(&mut held, &mut held_reader, 2, "transform");
    assert_eq!(r.get("ok"), Some(&Json::Bool(false)), "{r}");
    assert_eq!(r.get("code").unwrap().as_str(), Some("draining"));
    assert!(r.get("retry_after_ms").unwrap().as_f64().unwrap() > 0.0);

    // the in-flight request was admitted before drain — it must complete
    let a = inflight.join().unwrap();
    assert_eq!(a.get("ok"), Some(&Json::Bool(true)), "{a}");

    drop(held_reader);
    drop(held);
    // graceful shutdown: nothing queued is left, so the drain reports
    // clean and the join completes well inside the deadline
    let start = Instant::now();
    assert!(
        server.shutdown_graceful(),
        "no queued work should hit the drain cutoff"
    );
    assert!(
        start.elapsed() < Duration::from_secs(5),
        "drain must not consume the full deadline when idle"
    );
}

#[test]
fn max_conns_flood_gets_coded_overloaded_refusals() {
    let backend = Arc::new(NativeBackend::new(&[N], 1.0, 17));
    let c = Arc::new(Coordinator::start(
        config(64, Duration::from_micros(200)),
        backend,
    ));
    let opts = ServerOptions {
        max_conns: 2,
        ..Default::default()
    };
    let server = TcpServer::start_with(Arc::clone(&c), "127.0.0.1:0", opts).unwrap();
    let addr = server.addr();

    // fill both slots with live connections (and prove they serve)
    let mut held = Vec::new();
    for id in 0..2u64 {
        let mut s = TcpStream::connect(addr).unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let doc = request(&mut s, &mut r, id, "transform");
        assert_eq!(doc.get("ok"), Some(&Json::Bool(true)), "{doc}");
        held.push((s, r));
    }
    // flood: every connection past the cap gets the one-line refusal
    for _ in 0..4 {
        let s = TcpStream::connect(addr).unwrap();
        let mut r = BufReader::new(s);
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        let doc = Json::parse(line.trim()).unwrap();
        assert_eq!(doc.get("ok"), Some(&Json::Bool(false)), "{doc}");
        assert_eq!(doc.get("code").unwrap().as_str(), Some("overloaded"));
        assert!(doc.get("retry_after_ms").unwrap().as_f64().unwrap() > 0.0);
    }
    // slots free up once the held connections close: a new connection is
    // admitted again (prune happens on the next accept)
    drop(held);
    std::thread::sleep(Duration::from_millis(250));
    let mut s = TcpStream::connect(addr).unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    let doc = request(&mut s, &mut r, 9, "transform");
    assert_eq!(doc.get("ok"), Some(&Json::Bool(true)), "{doc}");
    drop(r);
    drop(s);
    server.shutdown();
}
