//! Integration tests over the real AOT artifacts (`make artifacts`).
//!
//! These exercise the full three-layer contract: Pallas/JAX-lowered HLO
//! text -> PJRT compile -> execute from Rust, checked against (a) golden
//! vectors computed by the Python side and (b) the native Rust backend.
//!
//! If `artifacts/manifest.json` is missing the tests skip with a notice so
//! plain `cargo test` stays usable before `make artifacts`.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use triplespin::coordinator::{self, Backend, Coordinator, NativeBackend, PjrtBackend};
use triplespin::runtime::{Op, RuntimeService};
use triplespin::util::rng::Rng;

fn artifact_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/manifest.json not found — run `make artifacts`");
        None
    }
}

#[test]
fn golden_vectors_verify_on_pjrt() {
    let Some(dir) = artifact_dir() else { return };
    let svc = RuntimeService::spawn(dir).expect("runtime loads all artifacts");
    let h = svc.handle();
    let names = h.names().unwrap();
    assert!(!names.is_empty());
    let mut checked = 0;
    for name in &names {
        if let Some((max_err, numel)) = h.verify_golden(name).expect("verify runs") {
            assert!(numel > 0);
            assert!(
                max_err < 2e-3,
                "{name}: PJRT output deviates from python golden by {max_err}"
            );
            checked += 1;
        }
    }
    assert!(checked >= 5, "expected golden vectors for most artifacts");
    svc.shutdown();
}

#[test]
fn pjrt_backend_matches_native_backend() {
    let Some(dir) = artifact_dir() else { return };
    let svc = RuntimeService::spawn(dir).expect("runtime spawns");
    let dims = [64usize, 256];
    let (sigma, seed) = (2.0, 77);
    let native = NativeBackend::new(&dims, sigma, seed);
    let pjrt = PjrtBackend::new(svc.handle(), &dims, sigma, seed).unwrap();

    let mut rng = Rng::new(5);
    for &n in &dims {
        for rows in [1usize, 3, 16] {
            let xs = rng.gaussian_vec(rows * n);
            // transform: exact same math, f32 tolerance
            let a = native.run_batch(Op::Transform, n, rows, &xs).unwrap();
            let b = pjrt.run_batch(Op::Transform, n, rows, &xs).unwrap();
            let (a, b) = (a.as_f32().unwrap(), b.as_f32().unwrap());
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(b) {
                assert!(
                    (x - y).abs() < 1e-2 * (1.0 + y.abs()),
                    "transform n={n} rows={rows}: {x} vs {y}"
                );
            }
            // crosspolytope: identical bucket ids
            let a = native.run_batch(Op::CrossPolytope, n, rows, &xs).unwrap();
            let b = pjrt.run_batch(Op::CrossPolytope, n, rows, &xs).unwrap();
            assert_eq!(
                a.as_i32().unwrap(),
                b.as_i32().unwrap(),
                "crosspolytope ids must agree exactly (n={n}, rows={rows})"
            );
        }
    }
    // rff on the n=256 lane
    let n = 256;
    let xs = rng.gaussian_vec(2 * n);
    let a = native.run_batch(Op::Rff, n, 2, &xs).unwrap();
    let b = pjrt.run_batch(Op::Rff, n, 2, &xs).unwrap();
    for (x, y) in a.as_f32().unwrap().iter().zip(b.as_f32().unwrap()) {
        assert!((x - y).abs() < 5e-3, "rff: {x} vs {y}");
    }
    svc.shutdown();
}

#[test]
fn coordinator_over_pjrt_end_to_end() {
    let Some(dir) = artifact_dir() else { return };
    let svc = RuntimeService::spawn(dir).expect("runtime spawns");
    let (sigma, seed) = (1.0, 42);
    let backend =
        Arc::new(PjrtBackend::new(svc.handle(), &[256], sigma, seed).unwrap());
    let config = coordinator::Config {
        lanes: vec![
            (Op::Transform, 256),
            (Op::Rff, 256),
            (Op::CrossPolytope, 256),
        ],
        max_batch: 16,
        max_wait: Duration::from_micros(300),
        queue_cap: 256,
        sigma,
        seed,
        ..coordinator::Config::default()
    };
    let c = Coordinator::start(config, backend);
    let native = NativeBackend::new(&[256], sigma, seed);

    let mut rng = Rng::new(9);
    let mut rxs = Vec::new();
    let mut inputs = Vec::new();
    for _ in 0..40 {
        let v = rng.gaussian_vec(256);
        inputs.push(v.clone());
        rxs.push(c.submit(Op::Transform, v).unwrap());
    }
    for ((_, rx), v) in rxs.into_iter().zip(&inputs) {
        let out = rx.recv().unwrap().result.unwrap();
        let got = out.as_f32().unwrap();
        let want = native.run_batch(Op::Transform, 256, 1, v).unwrap();
        let want = want.as_f32().unwrap();
        assert_eq!(got.len(), want.len());
        for (x, y) in got.iter().zip(want) {
            assert!((x - y).abs() < 1e-2 * (1.0 + y.abs()));
        }
    }
    // batching happened over the PJRT path too
    let m = c.metrics();
    let (_, tm) = m
        .iter()
        .find(|((op, _), _)| *op == Op::Transform)
        .unwrap();
    assert!(tm.mean_batch_size() > 1.0);
    c.shutdown();
    svc.shutdown();
}

#[test]
fn runtime_rejects_bad_inputs() {
    let Some(dir) = artifact_dir() else { return };
    let svc = RuntimeService::spawn(dir).expect("runtime spawns");
    let h = svc.handle();
    // unknown artifact
    assert!(h.run("nope_n1_b1", vec![]).is_err());
    // wrong input count
    assert!(h.run("transform_n64_b1", vec![vec![0.0; 64]]).is_err());
    // wrong numel
    assert!(h
        .run(
            "transform_n64_b1",
            vec![vec![0.0; 63], vec![0.0; 64], vec![0.0; 64], vec![0.0; 64]],
        )
        .is_err());
    svc.shutdown();
}
