//! Statistical contract of the binary embedding subsystem.
//!
//! Sign codes of random projections obey the SimHash identity: two unit
//! vectors at angle `θ` disagree on any one code bit with probability
//! exactly `θ/π` (for a Gaussian projection; the TripleSpin families match
//! it up to the paper's distributional guarantees). This file pins
//!
//! * the expected normalized Hamming distance against the angular-distance
//!   oracle `θ/π`, for the dense baseline and the fully discrete `hd3`;
//! * the Hamming LSH bucket-collision probability against the independent
//!   per-bit model `(1 - θ/π)^prefix_bits`;
//! * the 1-bit Gram estimate's expectation against the exact angular
//!   kernel `1 - 2θ/π`.

use triplespin::binary::{angular_estimate, BinaryEmbedding};
use triplespin::kernels::exact;
use triplespin::lsh::collision::pair_at_distance;
use triplespin::lsh::HammingLsh;
use triplespin::transform::Family;
use triplespin::util::rng::Rng;

/// Angle between two unit vectors at Euclidean distance `d` on the sphere.
fn theta(dist: f64) -> f64 {
    (1.0 - dist * dist / 2.0).clamp(-1.0, 1.0).acos()
}

/// Mean normalized Hamming distance between codes of pairs at `dist`,
/// averaged over `draws` independent embeddings × `pairs` pairs each.
fn mean_bit_flip_rate(family: Family, n: usize, dist: f64, draws: u64, pairs: usize) -> f64 {
    let mut total = 0.0;
    let mut count = 0usize;
    for d in 0..draws {
        let emb = BinaryEmbedding::with_family(family, n, &mut Rng::new(500 + d));
        let mut rng = Rng::new(9_000 + d * 31 + (dist * 1e3) as u64);
        for _ in 0..pairs {
            let (x, y) = pair_at_distance(n, dist, &mut rng);
            let h = emb.embed(&x).hamming(&emb.embed(&y));
            total += h as f64 / n as f64;
            count += 1;
        }
    }
    total / count as f64
}

#[test]
fn bit_flip_rate_matches_angular_oracle_dense() {
    // Gaussian projection: P[bit differs] = θ/π exactly — tight pin.
    let n = 256;
    for dist in [0.3f64, 0.7, 1.0, 1.4] {
        let want = theta(dist) / std::f64::consts::PI;
        let got = mean_bit_flip_rate(Family::Dense, n, dist, 6, 12);
        // ~18k bit samples per point: 4σ of a Bernoulli mean is well
        // under 0.02 at these rates
        assert!(
            (got - want).abs() < 0.02,
            "dense dist={dist}: flip rate {got} vs θ/π = {want}"
        );
    }
}

#[test]
fn bit_flip_rate_matches_angular_oracle_hd3() {
    // The paper's claim: the discrete chain reproduces the Gaussian
    // collision curve (Theorem 5.3 bounds the gap). Slightly looser pin —
    // hd3 code bits within one draw are correlated, so the variance of the
    // mean is higher than the independent-bit model.
    let n = 256;
    for dist in [0.3f64, 0.7, 1.0, 1.4] {
        let want = theta(dist) / std::f64::consts::PI;
        let got = mean_bit_flip_rate(Family::Hd3, n, dist, 8, 10);
        assert!(
            (got - want).abs() < 0.035,
            "hd3 dist={dist}: flip rate {got} vs θ/π = {want}"
        );
    }
}

#[test]
fn flip_rate_monotone_in_distance() {
    // closer pairs must collide more — the LSH property itself
    let n = 128;
    let rates: Vec<f64> = [0.2f64, 0.6, 1.0, 1.4, 1.8]
        .iter()
        .map(|&d| mean_bit_flip_rate(Family::Hd3, n, d, 4, 10))
        .collect();
    for w in rates.windows(2) {
        assert!(w[0] < w[1], "flip rate must increase with distance: {rates:?}");
    }
}

#[test]
fn prefix_bucket_collision_matches_independent_bit_model() {
    // A HammingLsh table's bucket key is a b-bit packed prefix code:
    // under the oracle, two points at angle θ share a bucket with
    // probability (1 - θ/π)^b. Pin the empirical collision rate of the
    // full index machinery (build + candidates) against that closed form.
    let n = 64;
    let b = 8;
    for dist in [0.4f64, 0.9] {
        let p_bit = 1.0 - theta(dist) / std::f64::consts::PI;
        let want = p_bit.powi(b as i32);
        let mut collisions = 0usize;
        let mut total = 0usize;
        for trial in 0..60u64 {
            let mut rng = Rng::new(3_000 + trial);
            let (x, y) = pair_at_distance(n, dist, &mut rng);
            // index holding only x, one table: y colliding == candidate hit
            let idx = HammingLsh::build(&[x], Family::Dense, n, 1, b, 40 + trial);
            if !idx.candidates(&y).is_empty() {
                collisions += 1;
            }
            total += 1;
        }
        let got = collisions as f64 / total as f64;
        // 60 Bernoulli trials: 3σ ≈ 0.19 at p=0.5; keep a generous band
        // but tight enough to catch a wrong exponent or broken bucketing
        assert!(
            (got - want).abs() < 0.2,
            "dist={dist}: bucket collision {got} vs (1-θ/π)^{b} = {want}"
        );
    }
    // and the two distances must order correctly
    let near = {
        let mut c = 0;
        for t in 0..40u64 {
            let mut rng = Rng::new(7_000 + t);
            let (x, y) = pair_at_distance(n, 0.3, &mut rng);
            let idx = HammingLsh::build(&[x], Family::Dense, n, 1, b, 80 + t);
            c += usize::from(!idx.candidates(&y).is_empty());
        }
        c
    };
    let far = {
        let mut c = 0;
        for t in 0..40u64 {
            let mut rng = Rng::new(7_000 + t);
            let (x, y) = pair_at_distance(n, 1.6, &mut rng);
            let idx = HammingLsh::build(&[x], Family::Dense, n, 1, b, 80 + t);
            c += usize::from(!idx.candidates(&y).is_empty());
        }
        c
    };
    assert!(near > far, "near pairs must collide more: near={near} far={far}");
}

#[test]
fn one_bit_kernel_estimate_is_unbiased_for_angular() {
    // E[1 - 2·d_H/k] = 1 - 2θ/π = the exact angular kernel.
    let n = 64;
    let k_bits = 256;
    let mut rng = Rng::new(11);
    let (x, y) = pair_at_distance(n, 0.8, &mut rng);
    let exact_val = exact::angular(&x, &y);
    for family in [Family::Dense, Family::Hd3] {
        let mut est = 0.0;
        let draws = 12u64;
        for d in 0..draws {
            let emb = BinaryEmbedding::new(triplespin::transform::make(
                family,
                k_bits,
                n,
                n,
                &mut Rng::new(600 + d),
            ));
            let h = emb.embed(&x).hamming(&emb.embed(&y));
            est += angular_estimate(h, k_bits);
        }
        est /= draws as f64;
        assert!(
            (est - exact_val).abs() < 0.06,
            "{family:?}: 1-bit estimate {est} vs exact angular {exact_val}"
        );
    }
}
