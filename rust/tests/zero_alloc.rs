//! Verifies the execution-engine acceptance criterion: after a `Workspace`
//! has been warmed, `Transform::apply_into` performs **zero heap
//! allocations** — all scratch comes from the reused workspace.
//!
//! A counting global allocator intercepts every alloc/realloc; the file
//! holds exactly one `#[test]` so no concurrent test can perturb the
//! counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use triplespin::transform::{make, make_square, Family, Transform};
use triplespin::util::rng::Rng;

struct CountingAlloc;

static ALLOCATIONS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

#[test]
fn apply_into_is_allocation_free_after_workspace_warmup() {
    let n = 128;
    let transforms: Vec<Box<dyn Transform>> = vec![
        make_square(Family::Hd3, n, &mut Rng::new(1)),
        make_square(Family::Hdg, n, &mut Rng::new(2)),
        make_square(Family::Circulant, n, &mut Rng::new(3)),
        make_square(Family::Toeplitz, n, &mut Rng::new(4)),
        make_square(Family::Hankel, n, &mut Rng::new(5)),
        make_square(Family::SkewCirculant, n, &mut Rng::new(6)),
        make_square(Family::Dense, n, &mut Rng::new(7)),
        // stacked shapes: multi-block, and truncated last block
        make(Family::Hd3, 3 * n, n, n, &mut Rng::new(8)),
        make(Family::Toeplitz, 40, n, 32, &mut Rng::new(9)),
    ];
    let x = Rng::new(10).gaussian_vec(n);
    for t in &transforms {
        let mut ws = t.make_workspace();
        let mut out = vec![0.0f32; t.dim_out()];
        // one more apply through the exact call path under test, so even a
        // first-use pool path cannot be blamed on the measured region
        t.apply_into(&x, &mut out, &mut ws);
        let before = ALLOCATIONS.load(Ordering::SeqCst);
        for _ in 0..16 {
            t.apply_into(&x, &mut out, &mut ws);
        }
        let after = ALLOCATIONS.load(Ordering::SeqCst);
        assert_eq!(
            before,
            after,
            "{}: apply_into allocated {} time(s) with a warm workspace",
            t.name(),
            after - before
        );
    }
}
