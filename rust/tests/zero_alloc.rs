//! Verifies the execution-engine acceptance criteria:
//!
//! 1. after a `Workspace` has been warmed, `Transform::apply_into` performs
//!    **zero heap allocations** — all scratch comes from the reused
//!    workspace;
//! 2. after one warmup batch, `Transform::apply_batch_into` through a
//!    persistent `WorkerPool` performs **zero heap allocations and zero
//!    thread spawns** per batch — worker threads and their pinned
//!    workspaces are reused verbatim (thread ids stay stable);
//! 3. `NativeBackend::run_batch` allocates only its output buffers
//!    (bounded constant per call — a per-batch thread spawn would blow the
//!    bound by an order of magnitude).
//!
//! A counting global allocator intercepts every alloc/realloc; the file
//! holds exactly one `#[test]` so no concurrent test can perturb the
//! counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use triplespin::coordinator::{Backend, NativeBackend};
use triplespin::linalg::Workspace;
use triplespin::runtime::{Op, WorkerPool};
use triplespin::transform::{make, make_square, Family, Transform};
use triplespin::util::rng::Rng;

struct CountingAlloc;

static ALLOCATIONS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn alloc_count() -> usize {
    ALLOCATIONS.load(Ordering::SeqCst)
}

fn check_apply_into_zero_alloc() {
    let n = 128;
    let transforms: Vec<Box<dyn Transform>> = vec![
        make_square(Family::Hd3, n, &mut Rng::new(1)),
        make_square(Family::Hdg, n, &mut Rng::new(2)),
        make_square(Family::Circulant, n, &mut Rng::new(3)),
        make_square(Family::Toeplitz, n, &mut Rng::new(4)),
        make_square(Family::Hankel, n, &mut Rng::new(5)),
        make_square(Family::SkewCirculant, n, &mut Rng::new(6)),
        make_square(Family::Dense, n, &mut Rng::new(7)),
        // stacked shapes: multi-block, and truncated last block
        make(Family::Hd3, 3 * n, n, n, &mut Rng::new(8)),
        make(Family::Toeplitz, 40, n, 32, &mut Rng::new(9)),
    ];
    let x = Rng::new(10).gaussian_vec(n);
    for t in &transforms {
        let mut ws = t.make_workspace();
        let mut out = vec![0.0f32; t.dim_out()];
        // one more apply through the exact call path under test, so even a
        // first-use pool path cannot be blamed on the measured region
        t.apply_into(&x, &mut out, &mut ws);
        let before = alloc_count();
        for _ in 0..16 {
            t.apply_into(&x, &mut out, &mut ws);
        }
        let after = alloc_count();
        assert_eq!(
            before,
            after,
            "{}: apply_into allocated {} time(s) with a warm workspace",
            t.name(),
            after - before
        );
    }
}

fn check_pooled_batch_zero_alloc_and_no_spawns() {
    let n = 128;
    let rows = 64; // 64 / MIN_ROWS_PER_WORKER = 8 >= 4 workers -> parallel
    let xs = Rng::new(20).gaussian_vec(rows * n);
    let transforms: Vec<Box<dyn Transform>> = vec![
        make_square(Family::Hd3, n, &mut Rng::new(21)),
        make_square(Family::Hdg, n, &mut Rng::new(22)),
        make_square(Family::Circulant, n, &mut Rng::new(23)),
        make_square(Family::Toeplitz, n, &mut Rng::new(24)),
        make_square(Family::Hankel, n, &mut Rng::new(25)),
        make_square(Family::SkewCirculant, n, &mut Rng::new(26)),
        make(Family::Hd3, 2 * n, n, n, &mut Rng::new(27)),
    ];
    // work gate disabled: these shapes must deterministically exercise the
    // parallel path (the gate itself is covered by unit tests)
    let pool = WorkerPool::with_min_work(4, 0);
    for t in &transforms {
        let mut out = vec![0.0f32; rows * t.dim_out()];
        // warmup: spawns the pool (first transform only) and warms every
        // worker's pinned workspace for this family's scratch shapes
        t.apply_batch_into(&xs, &mut out, &pool);
        t.apply_batch_into(&xs, &mut out, &pool);
        assert!(pool.started(), "this shape must engage the worker threads");
        let ids_before = pool.thread_ids();
        let before = alloc_count();
        for _ in 0..8 {
            t.apply_batch_into(&xs, &mut out, &pool);
        }
        let after = alloc_count();
        assert_eq!(
            before,
            after,
            "{}: pooled apply_batch_into allocated {} time(s) after warmup",
            t.name(),
            after - before
        );
        assert_eq!(
            pool.thread_ids(),
            ids_before,
            "{}: worker threads must be reused, never respawned per batch",
            t.name()
        );
    }
}

fn check_native_backend_bounded_allocs() {
    let n = 256;
    let rows = 64;
    let xs = Rng::new(30).gaussian_vec(rows * n);
    let be = NativeBackend::with_workers(&[n], 1.0, 31, 4);
    // (op, output allocations per call: result buffers only)
    let lanes = [(Op::Transform, 1usize), (Op::Rff, 2), (Op::CrossPolytope, 2)];
    for (op, allowed) in lanes {
        // warmup spawns the backend pool / warms scratch
        be.run_batch(op, n, rows, &xs).unwrap();
        be.run_batch(op, n, rows, &xs).unwrap();
        let iters = 8;
        let before = alloc_count();
        for _ in 0..iters {
            std::hint::black_box(be.run_batch(op, n, rows, &xs).unwrap());
        }
        let after = alloc_count();
        assert!(
            after - before <= iters * allowed,
            "{op}: {} allocations over {iters} batches (allowed {} per batch: \
             output buffers only — a per-batch thread spawn would far exceed this)",
            after - before,
            allowed
        );
    }
}

fn check_workspace_checkouts_zero_alloc() {
    // Both checkout flavors must be allocation-free once the pool holds a
    // buffer of the right capacity: the zeroed take_* pays only a memset,
    // the dirty take_*_uninit not even that.
    let mut ws = Workspace::new();
    for len in [64usize, 4096] {
        // warm: one allocation each for the f32 and f64 pool entries
        let warm32 = ws.take_f32(len);
        ws.put_f32(warm32);
        let warm64 = ws.take_f64(len);
        ws.put_f64(warm64);
        let before = alloc_count();
        for _ in 0..16 {
            let a = ws.take_f32_uninit(len);
            ws.put_f32(a);
            let b = ws.take_f32(len);
            ws.put_f32(b);
            let c = ws.take_f64_uninit(len);
            ws.put_f64(c);
            let d = ws.take_f64(len);
            ws.put_f64(d);
        }
        let after = alloc_count();
        assert_eq!(
            before,
            after,
            "len={len}: warm take/put (zeroed + uninit) allocated {} time(s)",
            after - before
        );
    }
}

#[test]
fn hot_paths_are_allocation_free_after_warmup() {
    check_workspace_checkouts_zero_alloc();
    check_apply_into_zero_alloc();
    check_pooled_batch_zero_alloc_and_no_spawns();
    check_native_backend_bounded_allocs();
}
