//! CLI smoke tests: run the `triplespin` binary end to end.

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_triplespin"))
}

fn have_artifacts() -> bool {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.json")
        .exists()
}

#[test]
fn help_exits_zero() {
    let out = bin().arg("help").output().expect("run");
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("serve"));
    assert!(text.contains("verify"));
}

#[test]
fn unknown_command_exits_nonzero() {
    let out = bin().arg("frobnicate").output().expect("run");
    assert!(!out.status.success());
}

#[test]
fn transform_prints_stats() {
    let out = bin()
        .args(["transform", "--family", "hd3", "--n", "128", "--seed", "7"])
        .output()
        .expect("run");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("HD3 HD2 HD1"));
    assert!(text.contains("params"));
}

#[test]
fn transform_binary_prints_packed_footprint() {
    let out = bin()
        .args(["transform", "--family", "hd3", "--n", "128", "--seed", "7", "--binary"])
        .output()
        .expect("run");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("128 code bits"));
    assert!(text.contains("32x smaller responses"));
    assert!(text.contains("code[..4]"));
}

#[test]
fn serve_binary_embed_op_smoke() {
    let out = bin()
        .args([
            "serve", "--requests", "50", "--n", "64", "--backend", "native", "--op",
            "binary_embed",
        ])
        .output()
        .expect("run");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("done: 50 requests"));
    assert!(text.contains("binary_embed_n64"));
}

#[test]
fn transform_rejects_bad_family_and_dim() {
    let out = bin()
        .args(["transform", "--family", "nope"])
        .output()
        .expect("run");
    assert!(!out.status.success());
    let out = bin()
        .args(["transform", "--family", "hd3", "--n", "100"])
        .output()
        .expect("run");
    assert!(!out.status.success(), "non-power-of-two n must be rejected");
}

#[test]
fn info_and_verify_with_artifacts() {
    if !have_artifacts() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let out = bin()
        .arg("info")
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("run");
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("transform_n256_b16"));

    let out = bin()
        .arg("verify")
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("run");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("OK"));
    assert!(!text.contains("FAIL"));
}

#[test]
fn serve_native_smoke() {
    let out = bin()
        .args(["serve", "--requests", "100", "--n", "64", "--backend", "native"])
        .output()
        .expect("run");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("done: 100 requests"));
    assert!(text.contains("metrics"));
}
