//! Chaos suite: the serving stack under deterministic fault injection.
//!
//! CI runs this as a named step (`cargo test --test chaos_serving`). Every
//! scenario drives a real `Coordinator` (some over a real `TcpServer`)
//! against a `FaultInjectingBackend` or a purpose-built hostile backend,
//! and asserts the fault-isolation contract:
//!
//! * every accepted request reaches a terminal response — no silent hangs;
//! * a backend panic fails at most its own request, never the lane;
//! * a lane-fatal failure is detected, counted, and healed by the
//!   supervisor (the lane serves again after its restart backoff);
//! * the circuit breaker opens under a failure streak, sheds fast, and
//!   closes after a successful half-open probe;
//! * a fault-free (no-op-plan) stack is bit-identical to the direct
//!   backend — the isolation machinery costs no determinism.
//!
//! The `net_faults_*` scenarios (their own named CI step) add transport
//! chaos: `TS_FAULT`-grammar `conn_drop`/`slow_read_ms`/`partial_write`
//! plans applied at the socket layer, driven through the resilient
//! `RetryClient` — every logical request must reach exactly one terminal
//! outcome, retryable refusals carry `retry_after_ms`, and non-retryable
//! codes are never retried.
//!
//! The `shard_*` scenarios (a third named CI step) lift the fault unit
//! from one backend or one socket to a whole shard: a `ShardRouter`
//! fronts a fleet of `ShardService` TCP servers, and individual shards
//! are killed (`down_after_ms`/`down_for_ms` windows), stalled, or made
//! flaky while queries flow. The fleet contract under shard loss:
//! scatter-gather answers are either exact (bit-identical to one global
//! index) or carry an explicit `partial` marker naming the missing
//! shards — never silently truncated, never hung — and service recovers
//! to exact answers once the dead shard returns.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use triplespin::coordinator::{
    server, Backend, ClientError, Config, Coordinator, CoordinatorService, FaultInjectingBackend,
    FaultPlan, IngressOptions, LineService, NativeBackend, RetryClient, RetryPolicy,
    ServerOptions, SubmitError, TcpServer,
};
use triplespin::router::{
    demo_points, merge_topk, RouterOptions, ShardIndex, ShardIndexConfig, ShardRouter,
    ShardService, ShardSpec,
};
use triplespin::runtime::{Op, Output};
use triplespin::util::json::Json;
use triplespin::util::rng::Rng;

const N: usize = 64;

fn base_config() -> Config {
    Config {
        lanes: vec![(Op::Transform, N)],
        max_batch: 4,
        max_wait: Duration::from_micros(200),
        queue_cap: 64,
        sigma: 1.0,
        seed: 5,
        restart_backoff: Duration::from_millis(5),
        restart_backoff_max: Duration::from_millis(50),
        ..Config::default()
    }
}

fn native() -> Arc<dyn Backend> {
    Arc::new(NativeBackend::new(&[N], 1.0, 5))
}

fn faulty(plan: &str) -> Arc<FaultInjectingBackend> {
    Arc::new(FaultInjectingBackend::new(
        native(),
        FaultPlan::parse(plan).unwrap(),
    ))
}

#[test]
fn every_request_reaches_a_terminal_response_under_faults() {
    // a hostile mix: panics, errors, and delays — yet every accepted
    // request must get exactly one terminal answer within bounded time
    let be = faulty("panic:0.2,err:0.2,delay_ms:1,seed:11");
    let cfg = Config {
        breaker_threshold: 0, // isolate: the breaker has its own scenario
        ..base_config()
    };
    let c = Coordinator::start(cfg, Arc::clone(&be) as Arc<dyn Backend>);
    let mut rng = Rng::new(1);
    let mut rxs = Vec::new();
    let mut accepted = 0;
    for _ in 0..150 {
        loop {
            match c.submit(Op::Transform, rng.gaussian_vec(N)) {
                Ok(p) => {
                    rxs.push(p);
                    accepted += 1;
                    break;
                }
                // transient shedding is legal; terminal silence is not
                Err(SubmitError::Busy | SubmitError::Unavailable | SubmitError::LaneDown) => {
                    std::thread::sleep(Duration::from_millis(1));
                }
                Err(e) => panic!("unexpected submit error: {e:?}"),
            }
        }
    }
    let (mut oks, mut errs) = (0, 0);
    for (id, rx) in rxs {
        let resp = rx
            .recv_timeout(Duration::from_secs(10))
            .expect("terminal response under chaos");
        assert_eq!(resp.id, id);
        match resp.result {
            Ok(out) => {
                assert_eq!(out.as_f32().unwrap().len(), N);
                oks += 1;
            }
            Err(_) => errs += 1,
        }
    }
    assert_eq!(oks + errs, accepted);
    assert!(oks > 0, "the fault mix must let some requests through");
    assert!(errs > 0, "a 40% fault rate must fail some requests");
    assert!(be.injected_panics.load(Ordering::Relaxed) > 0);
    let m = c.metrics();
    let (_, lm) = &m[0];
    assert!(lm.panics.load(Ordering::Relaxed) > 0, "panics counted");
    assert_eq!(
        lm.lane_failures.load(Ordering::Relaxed),
        0,
        "injected panics are caught per call — the lane itself never dies"
    );
    c.shutdown();
}

/// Backend returning a wrong-shape batch for its first `bad` calls — the
/// lane-fatal violation the supervisor must absorb and heal.
struct MalformedBackend {
    inner: NativeBackend,
    bad: AtomicU64,
}

impl Backend for MalformedBackend {
    fn run_batch(&self, op: Op, n: usize, rows: usize, xs: &[f32]) -> Result<Output, String> {
        let left = self.bad.load(Ordering::Relaxed);
        if left > 0 {
            self.bad.store(left - 1, Ordering::Relaxed);
            return Ok(Output::F32(vec![0.0])); // wrong length
        }
        self.inner.run_batch(op, n, rows, xs)
    }
    fn name(&self) -> &'static str {
        "malformed"
    }
}

#[test]
fn lane_recovers_after_lane_fatal_failures() {
    let be = Arc::new(MalformedBackend {
        inner: NativeBackend::new(&[N], 1.0, 5),
        bad: AtomicU64::new(2), // two consecutive lane deaths -> backoff doubles
    });
    let c = Coordinator::start(base_config(), be);
    let m = c.metrics();
    let (_, lm) = &m[0];
    // drive traffic until both malformed calls have each killed the lane;
    // requests may be lost to a death (disconnected reply -> error) or
    // shed with LaneDown during the backoff — but they must never hang
    let deadline = Instant::now() + Duration::from_secs(10);
    while lm.restarts.load(Ordering::Relaxed) < 2 {
        assert!(Instant::now() < deadline, "supervisor must restart the lane");
        let _ = c.call_timeout(Op::Transform, vec![1.0; N], Duration::from_millis(500));
        std::thread::sleep(Duration::from_millis(2));
    }
    assert!(lm.lane_failures.load(Ordering::Relaxed) >= 2);
    // the healed lane serves again, and health reports it open
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        match c.call_timeout(Op::Transform, vec![1.0; N], Duration::from_secs(1)) {
            Ok(out) => {
                assert_eq!(out.as_f32().unwrap().len(), N);
                break;
            }
            Err(_) => {
                assert!(Instant::now() < deadline, "restarted lane must serve");
                std::thread::sleep(Duration::from_millis(5));
            }
        }
    }
    let h = c.health_json();
    let lane = h.get(&format!("transform_n{N}")).unwrap();
    assert_eq!(lane.get("state").unwrap().as_str(), Some("open"));
    assert!(lane.get("restarts").unwrap().as_f64().unwrap() >= 2.0);
    c.shutdown();
}

/// Backend whose failure mode is toggled at runtime.
struct SwitchableBackend {
    inner: NativeBackend,
    failing: AtomicBool,
}

impl Backend for SwitchableBackend {
    fn run_batch(&self, op: Op, n: usize, rows: usize, xs: &[f32]) -> Result<Output, String> {
        if self.failing.load(Ordering::Relaxed) {
            Err("dependency down".into())
        } else {
            self.inner.run_batch(op, n, rows, xs)
        }
    }
    fn name(&self) -> &'static str {
        "switchable"
    }
}

#[test]
fn breaker_opens_and_closes_on_the_wire() {
    let be = Arc::new(SwitchableBackend {
        inner: NativeBackend::new(&[N], 1.0, 5),
        failing: AtomicBool::new(true),
    });
    let cfg = Config {
        breaker_threshold: 2,
        breaker_cooldown: Duration::from_millis(100),
        ..base_config()
    };
    let c = Coordinator::start(cfg, Arc::clone(&be));
    let vec_json: String = (0..N)
        .map(|i| format!("{}", i as f32 / 8.0))
        .collect::<Vec<_>>()
        .join(",");
    let line = |id: u64| format!(r#"{{"id": {id}, "op": "transform", "vector": [{vec_json}]}}"#);
    // two consecutive failures open the breaker...
    for id in 1..=2 {
        let r = triplespin::coordinator::server::process_line(&line(id), &c);
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(r.get("code").unwrap().as_str(), Some("backend"));
    }
    // ...so the next request is shed fast with code "unavailable"
    let r = triplespin::coordinator::server::process_line(&line(3), &c);
    assert_eq!(r.get("code").unwrap().as_str(), Some("unavailable"), "{r}");
    let h = c.health_json();
    let lane = h.get(&format!("transform_n{N}")).unwrap();
    assert_eq!(lane.get("state").unwrap().as_str(), Some("degraded"));
    // heal the dependency and wait out the cooldown: the half-open probe
    // closes the breaker and traffic flows again
    be.failing.store(false, Ordering::Relaxed);
    std::thread::sleep(Duration::from_millis(120));
    let r = triplespin::coordinator::server::process_line(&line(4), &c);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
    let h = c.health_json();
    let lane = h.get(&format!("transform_n{N}")).unwrap();
    assert_eq!(lane.get("state").unwrap().as_str(), Some("open"));
    c.shutdown();
}

#[test]
fn tcp_chaos_every_line_gets_a_parseable_reply() {
    // three pipelining clients against a panicky/flaky backend over a real
    // socket: the wire contract (one valid JSON reply per line, with ok
    // bool and, on failure, a code) must hold under chaos, and shutdown
    // must still join cleanly
    let be = faulty("panic:0.3,err:0.3,seed:3");
    let cfg = Config {
        breaker_threshold: 0,
        ..base_config()
    };
    let c = Arc::new(Coordinator::start(cfg, be as Arc<dyn Backend>));
    let server = TcpServer::start(Arc::clone(&c), "127.0.0.1:0").unwrap();
    let addr = server.addr();
    let mut joins = Vec::new();
    for t in 0..3u64 {
        joins.push(std::thread::spawn(move || {
            let mut stream = TcpStream::connect(addr).unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let vals: Vec<String> = (0..N).map(|i| format!("{}", (i as f32) + t as f32)).collect();
            let per_client = 20;
            for id in 0..per_client {
                let line = format!(
                    "{{\"id\": {id}, \"op\": \"transform\", \"vector\": [{}]}}\n",
                    vals.join(",")
                );
                stream.write_all(line.as_bytes()).unwrap();
            }
            let (mut oks, mut errs) = (0, 0);
            for id in 0..per_client {
                let mut resp = String::new();
                reader.read_line(&mut resp).unwrap();
                let doc = Json::parse(resp.trim()).expect("every reply parses");
                assert_eq!(doc.get("id").unwrap().as_f64(), Some(id as f64));
                match doc.get("ok") {
                    Some(&Json::Bool(true)) => oks += 1,
                    Some(&Json::Bool(false)) => {
                        assert!(doc.get("code").is_some(), "failures carry a code: {doc}");
                        errs += 1;
                    }
                    other => panic!("reply without ok bool: {other:?}"),
                }
            }
            (oks, errs)
        }));
    }
    let (mut oks, mut errs) = (0, 0);
    for j in joins {
        let (o, e) = j.join().unwrap();
        oks += o;
        errs += e;
    }
    assert_eq!(oks + errs, 60, "every line answered");
    assert!(oks > 0 && errs > 0, "chaos mix: {oks} ok / {errs} err");
    server.shutdown();
}

#[test]
fn deadline_expires_on_the_wire() {
    // a 150ms backend with a single-row batch: a queued request with a
    // 30ms timeout_ms must come back code "deadline" without waiting for
    // the backend to reach it
    let be = faulty("delay_ms:150");
    let cfg = Config {
        max_batch: 1,
        ..base_config()
    };
    let c = Arc::new(Coordinator::start(cfg, be as Arc<dyn Backend>));
    let server = TcpServer::start(Arc::clone(&c), "127.0.0.1:0").unwrap();
    let addr = server.addr();
    let vals: Vec<String> = (0..N).map(|i| format!("{}", i as f32)).collect();
    // connection A occupies the lane with an undeadlined request
    let mut a = TcpStream::connect(addr).unwrap();
    let mut a_reader = BufReader::new(a.try_clone().unwrap());
    a.write_all(
        format!("{{\"id\": 1, \"op\": \"transform\", \"vector\": [{}]}}\n", vals.join(","))
            .as_bytes(),
    )
    .unwrap();
    std::thread::sleep(Duration::from_millis(20)); // let A reach the backend
    // connection B queues behind it with a 30ms deadline
    let mut b = TcpStream::connect(addr).unwrap();
    let mut b_reader = BufReader::new(b.try_clone().unwrap());
    b.write_all(
        format!(
            "{{\"id\": 2, \"op\": \"transform\", \"vector\": [{}], \"timeout_ms\": 30}}\n",
            vals.join(",")
        )
        .as_bytes(),
    )
    .unwrap();
    let mut resp = String::new();
    b_reader.read_line(&mut resp).unwrap();
    let doc = Json::parse(resp.trim()).unwrap();
    assert_eq!(doc.get("ok"), Some(&Json::Bool(false)), "{doc}");
    assert_eq!(doc.get("code").unwrap().as_str(), Some("deadline"), "{doc}");
    assert_eq!(
        doc.get("error").unwrap().as_str(),
        Some("deadline exceeded")
    );
    // A's request still completes normally
    let mut resp = String::new();
    a_reader.read_line(&mut resp).unwrap();
    let doc = Json::parse(resp.trim()).unwrap();
    assert_eq!(doc.get("ok"), Some(&Json::Bool(true)), "{doc}");
    let m = c.metrics();
    let (_, lm) = &m[0];
    assert_eq!(lm.expired.load(Ordering::Relaxed), 1);
    server.shutdown();
}

#[test]
fn fault_free_stack_is_bit_identical_to_direct_backend() {
    // determinism unaffected when faults are off: the whole supervised /
    // breakered / deadline-aware stack over a no-op-plan injector must
    // produce byte-identical outputs to a direct backend call
    let inner = native();
    let wrapped = Arc::new(FaultInjectingBackend::new(
        Arc::clone(&inner),
        FaultPlan::default(),
    ));
    let direct = NativeBackend::new(&[N], 1.0, 5);
    let c = Coordinator::start(base_config(), wrapped);
    let mut rng = Rng::new(9);
    for _ in 0..25 {
        let v = rng.gaussian_vec(N);
        let got = c.call(Op::Transform, v.clone()).unwrap();
        let want = direct.run_batch(Op::Transform, N, 1, &v).unwrap();
        assert_eq!(got, want, "fault-free serving must be bit-identical");
    }
    let m = c.metrics();
    let (_, lm) = &m[0];
    assert_eq!(lm.failed.load(Ordering::Relaxed), 0);
    assert_eq!(lm.panics.load(Ordering::Relaxed), 0);
    assert_eq!(lm.lane_failures.load(Ordering::Relaxed), 0);
    c.shutdown();
}

/// A fast retry policy for tests: tight backoffs and a budget generous
/// enough that convergence, not budget pressure, is what's under test
/// (budget exhaustion has its own unit scenario in `coordinator::client`).
fn test_policy() -> RetryPolicy {
    RetryPolicy {
        max_attempts: 8,
        base_backoff: Duration::from_millis(1),
        max_backoff: Duration::from_millis(20),
        budget_max: 50.0,
        ..RetryPolicy::default()
    }
}

#[test]
fn net_faults_every_logical_request_reaches_exactly_one_terminal_outcome() {
    // a healthy backend behind a hostile transport: ~25% of replies are
    // swallowed (connection dropped), ~15% truncated mid-line, every
    // request stalled 1ms. The retry client must reconnect/resend until
    // each *logical* request reaches exactly one terminal outcome — and
    // since compute is deterministic and the backend healthy, that
    // outcome is success.
    let c = Arc::new(Coordinator::start(base_config(), native()));
    let opts = ServerOptions {
        net_faults: FaultPlan::parse("conn_drop:0.15,partial_write:0.1,slow_read_ms:1,seed:7")
            .unwrap(),
        ..Default::default()
    };
    let server = TcpServer::start_with(Arc::clone(&c), "127.0.0.1:0", opts).unwrap();
    let addr = server.addr().to_string();
    let mut joins = Vec::new();
    for t in 0..3u64 {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || {
            let client = RetryClient::connect(&addr, Some(&format!("c{t}")), test_policy());
            let v: Vec<f32> = (0..N).map(|i| (i as f32) + t as f32).collect();
            let mut outcomes = 0u64;
            for _ in 0..15 {
                match client.call("transform", &v) {
                    Ok(result) => {
                        assert_eq!(result.as_arr().unwrap().len(), N);
                        outcomes += 1;
                    }
                    Err(e) => panic!("healthy backend must converge to success: {e}"),
                }
            }
            (
                outcomes,
                client.retries.load(Ordering::Relaxed),
                client.reconnects.load(Ordering::Relaxed),
            )
        }));
    }
    let (mut outcomes, mut retries, mut reconnects) = (0, 0, 0);
    for j in joins {
        let (o, r, rc) = j.join().unwrap();
        outcomes += o;
        retries += r;
        reconnects += rc;
    }
    assert_eq!(outcomes, 45, "exactly one terminal outcome per logical request");
    assert!(retries > 0, "a ~24% transport fault rate must force retries");
    assert!(reconnects > 0, "dropped connections must force reconnects");
    // the server stayed consistent under the chaos: it completed at least
    // the 45 acknowledged requests (resends of swallowed replies recompute)
    let m = c.metrics();
    let (_, lm) = &m[0];
    assert!(lm.completed.load(Ordering::Relaxed) >= 45);
    server.shutdown();
}

#[test]
fn net_faults_retry_client_never_retries_non_retryable_codes() {
    let c = Arc::new(Coordinator::start(base_config(), native()));
    let server = TcpServer::start(Arc::clone(&c), "127.0.0.1:0").unwrap();
    let addr = server.addr().to_string();
    let client = RetryClient::connect(&addr, Some("strict"), test_policy());
    // wrong dimension: a terminal bad_dim — exactly one attempt, no retry
    match client.call("transform", &[1.0, 2.0]) {
        Err(ClientError::Rejected { code, .. }) => assert_eq!(code, "bad_dim"),
        other => panic!("expected a terminal rejection, got {other:?}"),
    }
    assert_eq!(client.attempts.load(Ordering::Relaxed), 1);
    assert_eq!(client.retries.load(Ordering::Relaxed), 0);
    // unknown op: bad_request, also terminal
    match client.call("nope", &[1.0; N]) {
        Err(ClientError::Rejected { code, .. }) => assert_eq!(code, "bad_request"),
        other => panic!("expected a terminal rejection, got {other:?}"),
    }
    assert_eq!(client.retries.load(Ordering::Relaxed), 0);
    server.shutdown();
}

#[test]
fn net_faults_throttled_on_the_wire_carries_hint_and_client_converges() {
    // admission: burst covers exactly one n=64 transform (1344 work units
    // + slack), refilling at 20k units/s — the second immediate request
    // must be refused `throttled` with a retry_after_ms the client then
    // honors to converge on a later attempt
    let cfg = Config {
        admission_rate: 20_000.0,
        admission_burst: 1_400.0,
        ..base_config()
    };
    let c = Arc::new(Coordinator::start(cfg, native()));
    let server = TcpServer::start(Arc::clone(&c), "127.0.0.1:0").unwrap();
    let addr = server.addr().to_string();
    // raw wire first: observe the refusal shape itself
    let mut stream = TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let vals: Vec<String> = (0..N).map(|i| format!("{}", i as f32)).collect();
    let line = |id: u64| {
        format!(
            "{{\"id\": {id}, \"op\": \"transform\", \"vector\": [{}], \"client_id\": \"hog\"}}\n",
            vals.join(",")
        )
    };
    stream.write_all(line(1).as_bytes()).unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    let doc = Json::parse(resp.trim()).unwrap();
    assert_eq!(doc.get("ok"), Some(&Json::Bool(true)), "{doc}");
    stream.write_all(line(2).as_bytes()).unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    let doc = Json::parse(resp.trim()).unwrap();
    assert_eq!(doc.get("ok"), Some(&Json::Bool(false)), "{doc}");
    assert_eq!(doc.get("code").unwrap().as_str(), Some("throttled"), "{doc}");
    assert!(
        doc.get("retry_after_ms").unwrap().as_f64().unwrap() >= 1.0,
        "throttled must carry a positive retry hint: {doc}"
    );
    // re-drain the bucket *immediately* before the client attempt so the
    // first attempt deterministically lands throttled regardless of how
    // long the raw-wire section above took (the bucket refills in real
    // time); then the client waits out the hint and converges
    let mut id = 3;
    loop {
        stream.write_all(line(id).as_bytes()).unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        let doc = Json::parse(resp.trim()).unwrap();
        if doc.get("code").and_then(|c| c.as_str()) == Some("throttled") {
            break;
        }
        id += 1;
        assert!(id < 64, "a 20k/s bucket must exhaust under tight-loop load");
    }
    let client = RetryClient::connect(&addr, Some("hog"), test_policy());
    let v: Vec<f32> = (0..N).map(|i| i as f32).collect();
    let result = client.call("transform", &v).expect("must converge after refill");
    assert_eq!(result.as_arr().unwrap().len(), N);
    assert!(
        client.retries.load(Ordering::Relaxed) >= 1,
        "the drained bucket must force at least one throttled retry"
    );
    let m = c.metrics();
    let (_, lm) = &m[0];
    assert!(lm.throttled.load(Ordering::Relaxed) >= 2);
    drop(reader);
    drop(stream);
    server.shutdown();
}

#[test]
fn net_faults_drain_under_load_gives_every_admitted_request_a_terminal_answer() {
    // 4 requests against a 1-row/50ms lane, then drain with a deadline
    // shorter than the remaining work: some complete, the rest get typed
    // `deadline` answers at the cutoff — but every admitted request gets
    // exactly one terminal reply, and nothing is silently dropped
    let be = faulty("delay_ms:50");
    let cfg = Config {
        max_batch: 1,
        ..base_config()
    };
    let c = Arc::new(Coordinator::start(cfg, be as Arc<dyn Backend>));
    let opts = ServerOptions {
        drain_deadline: Duration::from_millis(120),
        ..Default::default()
    };
    let server = TcpServer::start_with(Arc::clone(&c), "127.0.0.1:0", opts).unwrap();
    let addr = server.addr();
    let mut joins = Vec::new();
    for t in 0..4u64 {
        joins.push(std::thread::spawn(move || {
            let mut stream = TcpStream::connect(addr).unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let vals: Vec<String> = (0..N).map(|i| format!("{}", (i + 1) as f32)).collect();
            stream
                .write_all(
                    format!(
                        "{{\"id\": {t}, \"op\": \"transform\", \"vector\": [{}]}}\n",
                        vals.join(",")
                    )
                    .as_bytes(),
                )
                .unwrap();
            let mut resp = String::new();
            reader.read_line(&mut resp).unwrap();
            let doc = Json::parse(resp.trim()).expect("terminal reply parses");
            match doc.get("ok") {
                Some(&Json::Bool(true)) => "ok".to_string(),
                Some(&Json::Bool(false)) => doc
                    .get("code")
                    .and_then(|c| c.as_str())
                    .expect("failures carry a code")
                    .to_string(),
                other => panic!("reply without ok bool: {other:?}"),
            }
        }));
    }
    // let the requests land in the lane queue before draining
    std::thread::sleep(Duration::from_millis(30));
    let clean = server.shutdown_graceful();
    let outcomes: Vec<String> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    assert_eq!(outcomes.len(), 4, "every admitted request answered");
    let oks = outcomes.iter().filter(|o| *o == "ok").count();
    let cut = outcomes.iter().filter(|o| *o == "deadline").count();
    assert_eq!(
        oks + cut,
        4,
        "outcomes are exactly ok or typed deadline: {outcomes:?}"
    );
    assert!(oks >= 1, "work in flight at drain start must complete: {outcomes:?}");
    if cut > 0 {
        assert!(!clean, "a cutoff means the drain deadline was hit");
    }
    // drain state is observable after the fact
    assert!(c.is_draining());
    assert_eq!(c.pending(), 0, "no job left behind after drain");
}

// ---------------------------------------------------------------------------
// shard_* lane: whole-shard chaos against the fleet tier (`ShardRouter`
// over `ShardService` TCP servers). CI runs these as their own named step
// (`cargo test --test chaos_serving shard_`).
// ---------------------------------------------------------------------------

const FLEET_SEED: u64 = 71;
const FLEET_POINTS: usize = 240;
const K: usize = 12;

fn fleet_index(shard: usize, shards: usize) -> ShardIndex {
    ShardIndex::build(
        &demo_points(N, FLEET_POINTS, FLEET_SEED),
        &ShardIndexConfig {
            n: N,
            tables: 6,
            prefix_bits: 10,
            seed: FLEET_SEED,
            shard,
            shards,
        },
    )
}

/// A shard process in miniature: coordinator + local index slice, served
/// over TCP with an optional `TS_FAULT` net-fault plan (`""` = healthy).
fn spawn_fleet_shard(shard: usize, shards: usize, plan: &str) -> TcpServer {
    let c = Arc::new(Coordinator::start(base_config(), native()));
    let service = Arc::new(ShardService::new(c, fleet_index(shard, shards)));
    let opts = ServerOptions {
        net_faults: if plan.is_empty() {
            FaultPlan::default()
        } else {
            FaultPlan::parse(plan).unwrap()
        },
        ..Default::default()
    };
    server::serve(service, "127.0.0.1:0", opts).unwrap()
}

fn fleet_specs(groups: &[Vec<std::net::SocketAddr>]) -> Vec<ShardSpec> {
    groups
        .iter()
        .enumerate()
        .map(|(i, eps)| ShardSpec {
            name: format!("s{i}"),
            endpoints: eps.iter().map(|a| a.to_string()).collect(),
        })
        .collect()
}

fn fleet_opts() -> RouterOptions {
    RouterOptions {
        attempt_timeout: Duration::from_millis(500),
        scatter_budget: Duration::from_millis(1500),
        probe_interval: Duration::from_millis(25),
        probe_timeout: Duration::from_millis(150),
        breaker_cooldown: Duration::from_millis(60),
        ..RouterOptions::default()
    }
}

/// One request, one reply, over a fresh connection with a hard read
/// timeout — a hang surfaces as a test failure, never as a stuck run.
fn fleet_request(addr: std::net::SocketAddr, line: &str) -> Json {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(5)))
        .unwrap();
    stream.write_all(line.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    let mut reader = BufReader::new(stream);
    let mut resp = String::new();
    reader
        .read_line(&mut resp)
        .expect("a terminal reply, not a hang");
    Json::parse(resp.trim()).expect("reply parses")
}

fn lsh_line(id: u64, q: &[f32], k: usize) -> String {
    let vals: Vec<String> = q.iter().map(|x| format!("{x}")).collect();
    format!(
        "{{\"id\": {id}, \"op\": \"lsh_query\", \"vector\": [{}], \"k\": {k}}}",
        vals.join(",")
    )
}

/// Decode the flat interleaved `[id0, d0, id1, d1, ...]` wire result.
fn result_pairs(doc: &Json) -> Vec<(u32, u64)> {
    let Some(Json::Arr(items)) = doc.get("result") else {
        panic!("reply without a result array: {doc:?}");
    };
    assert_eq!(items.len() % 2, 0, "result must be flat (id, distance) pairs");
    items
        .chunks(2)
        .map(|c| match (&c[0], &c[1]) {
            (Json::Num(id), Json::Num(d)) => (*id as u32, *d as u64),
            other => panic!("non-numeric pair {other:?}"),
        })
        .collect()
}

fn degraded_names(doc: &Json) -> Vec<String> {
    match doc.get("degraded") {
        Some(Json::Arr(items)) => items
            .iter()
            .map(|s| s.as_str().expect("degraded entries are strings").to_string())
            .collect(),
        None => Vec::new(),
        other => panic!("bad degraded field {other:?}"),
    }
}

#[test]
fn shard_kill_window_yields_marked_partials_then_exact_recovery() {
    // The acceptance chaos proof: 3 shards, one killed mid-load by a
    // deterministic TS_FAULT down window. Queries before the window are
    // exact; during it they degrade to top-k over the surviving shards
    // with an explicit `partial` marker naming the dead shard; after it
    // the fleet heals back to exact answers. Never a silent truncation
    // (full replies are compared element-for-element against one global
    // index), never a hang (every read is under a timeout).
    let locals: Vec<ShardIndex> = (0..3).map(|i| fleet_index(i, 3)).collect();
    let global = fleet_index(0, 1);
    let shards = vec![
        spawn_fleet_shard(0, 3, ""),
        spawn_fleet_shard(1, 3, ""),
        spawn_fleet_shard(2, 3, "down_after_ms:400,down_for_ms:700"),
    ];
    let specs = fleet_specs(&[
        vec![shards[0].addr()],
        vec![shards[1].addr()],
        vec![shards[2].addr()],
    ]);
    let front = server::serve(
        Arc::new(ShardRouter::new(specs, fleet_opts())),
        "127.0.0.1:0",
        ServerOptions::default(),
    )
    .unwrap();

    let (mut full_before, mut saw_partial, mut full_after) = (false, false, false);
    let start = Instant::now();
    let mut seq = 0u64;
    while start.elapsed() < Duration::from_secs(10) && !(full_before && saw_partial && full_after) {
        seq += 1;
        let q = Rng::new(1000 + seq).unit_vec(N);
        let doc = fleet_request(front.addr(), &lsh_line(seq, &q, K));
        assert_eq!(
            doc.get("ok"),
            Some(&Json::Bool(true)),
            "two healthy shards must always produce an answer: {doc:?}"
        );
        let pairs = result_pairs(&doc);
        let degraded = degraded_names(&doc);
        if degraded.is_empty() {
            assert!(doc.get("code").is_none(), "full replies carry no code: {doc:?}");
            assert_eq!(
                pairs,
                global.query(&q, K),
                "a full reply must be exact, never silently truncated"
            );
            if saw_partial {
                full_after = true;
            } else {
                full_before = true;
            }
        } else {
            assert_eq!(doc.get("code").and_then(|c| c.as_str()), Some("partial"));
            assert!(
                degraded.contains(&"s2".to_string()),
                "only the killed shard may go missing: {degraded:?}"
            );
            let alive: Vec<Vec<(u32, u64)>> = (0..3)
                .filter(|i| !degraded.contains(&format!("s{i}")))
                .map(|i| locals[i].query(&q, K))
                .collect();
            assert_eq!(
                pairs,
                merge_topk(&alive, K),
                "a partial reply is exactly the surviving shards' merge"
            );
            saw_partial = true;
        }
        std::thread::sleep(Duration::from_millis(15));
    }
    assert!(full_before, "no exact answer seen before the kill window");
    assert!(saw_partial, "the kill window never surfaced as a marked partial");
    assert!(full_after, "the fleet never healed back to exact answers");
    front.shutdown();
    for s in shards {
        s.shutdown();
    }
}

#[test]
fn shard_replica_failover_keeps_answers_exact_during_primary_kill() {
    // Group s0 has a dead-from-birth primary and a healthy replica: both
    // the scatter path and the compute path must fail over inside the
    // group, so no query ever degrades — and the probe loop must trip
    // the dead primary's breaker while leaving the replica admitted.
    let global = fleet_index(0, 1);
    let s0_dead = spawn_fleet_shard(0, 2, "down_after_ms:0");
    let s0_replica = spawn_fleet_shard(0, 2, "");
    let s1 = spawn_fleet_shard(1, 2, "");
    let specs = fleet_specs(&[vec![s0_dead.addr(), s0_replica.addr()], vec![s1.addr()]]);
    let front = server::serve(
        Arc::new(ShardRouter::new(specs, fleet_opts())),
        "127.0.0.1:0",
        ServerOptions::default(),
    )
    .unwrap();

    for i in 0..10u64 {
        let q = Rng::new(2000 + i).unit_vec(N);
        let doc = fleet_request(front.addr(), &lsh_line(i, &q, K));
        assert_eq!(doc.get("ok"), Some(&Json::Bool(true)), "{doc:?}");
        assert!(
            doc.get("code").is_none(),
            "replica failover must not degrade the answer: {doc:?}"
        );
        assert_eq!(result_pairs(&doc), global.query(&q, K));
    }
    let client = RetryClient::connect(&front.addr().to_string(), Some("fleet"), test_policy());
    let v: Vec<f32> = (0..N).map(|i| (i % 7) as f32).collect();
    let result = client.call("transform", &v).expect("transform served by the fleet");
    assert_eq!(result.as_arr().unwrap().len(), N);
    // probes discover the dead primary: its breaker leaves the healthy
    // phase while the replica stays open
    let deadline = Instant::now() + Duration::from_secs(4);
    loop {
        let doc = fleet_request(front.addr(), "{\"id\": 99, \"op\": \"health\"}");
        let result = doc.get("result").expect("health carries a result");
        let Some(Json::Arr(eps)) = result.get("s0") else {
            panic!("health must list group s0: {doc:?}");
        };
        let states: Vec<&str> = eps
            .iter()
            .map(|e| e.get("state").and_then(|s| s.as_str()).unwrap())
            .collect();
        if states.contains(&"open") && states.iter().any(|s| *s != "open") {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "probes never tripped the dead primary's breaker: {states:?}"
        );
        std::thread::sleep(Duration::from_millis(25));
    }
    front.shutdown();
    s0_dead.shutdown();
    s0_replica.shutdown();
    s1.shutdown();
}

#[test]
fn shard_down_refusals_are_typed_retryable_and_the_client_converges() {
    // A single-shard fleet whose only endpoint is inside a down window:
    // the router refuses with the typed, hinted `shard_down` — and the
    // retry client treats it as retryable, converging to success once the
    // window closes and the probe loop re-admits the shard.
    let s0 = spawn_fleet_shard(0, 1, "down_after_ms:0,down_for_ms:800");
    let specs = fleet_specs(&[vec![s0.addr()]]);
    let front = server::serve(
        Arc::new(ShardRouter::new(specs, fleet_opts())),
        "127.0.0.1:0",
        ServerOptions::default(),
    )
    .unwrap();

    let q = Rng::new(3000).unit_vec(N);
    let doc = fleet_request(front.addr(), &lsh_line(1, &q, K));
    assert_eq!(doc.get("ok"), Some(&Json::Bool(false)), "{doc:?}");
    assert_eq!(doc.get("code").and_then(|c| c.as_str()), Some("shard_down"));
    assert_eq!(
        doc.get("retry_after_ms"),
        Some(&Json::Num(250.0)),
        "shard_down is retryable and must carry its hint: {doc:?}"
    );

    let client = RetryClient::connect(&front.addr().to_string(), Some("conv"), test_policy());
    let v: Vec<f32> = (0..N).map(|i| (i % 5) as f32).collect();
    let result = client
        .call("transform", &v)
        .expect("converges once the down window closes");
    assert_eq!(result.as_arr().unwrap().len(), N);
    assert!(
        client.retries.load(Ordering::Relaxed) >= 1,
        "the first attempts land inside the window and must be retried"
    );
    front.shutdown();
    s0.shutdown();
}

#[test]
fn shard_chaos_every_query_reaches_exactly_one_terminal_outcome() {
    // Mixed fleet chaos — one flaky shard (30% connection drops), one
    // healthy, one with a kill window — under concurrent compute and
    // scatter traffic. Every logical request must reach exactly one
    // terminal outcome: an ok (possibly marked partial) or a typed
    // refusal. A fresh connection plus hard read timeout per query turns
    // any hang or silent drop into a test failure.
    let shards = vec![
        spawn_fleet_shard(0, 3, "conn_drop:0.3,seed:13"),
        spawn_fleet_shard(1, 3, ""),
        spawn_fleet_shard(2, 3, "down_after_ms:100,down_for_ms:400"),
    ];
    let specs = fleet_specs(&[
        vec![shards[0].addr()],
        vec![shards[1].addr()],
        vec![shards[2].addr()],
    ]);
    let front = server::serve(
        Arc::new(ShardRouter::new(specs, fleet_opts())),
        "127.0.0.1:0",
        ServerOptions::default(),
    )
    .unwrap();
    let addr = front.addr();
    let mut joins = Vec::new();
    for t in 0..3u64 {
        joins.push(std::thread::spawn(move || {
            let client =
                RetryClient::connect(&addr.to_string(), Some(&format!("c{t}")), test_policy());
            let mut outcomes: Vec<String> = Vec::new();
            for i in 0..6u64 {
                let v = Rng::new(4000 + t * 100 + i).unit_vec(N);
                outcomes.push(match client.call("transform", &v) {
                    Ok(result) => {
                        assert_eq!(result.as_arr().unwrap().len(), N);
                        "ok".to_string()
                    }
                    Err(e) => format!("refused:{e}"),
                });
                let doc = fleet_request(addr, &lsh_line(t * 100 + i, &v, K));
                match doc.get("ok") {
                    Some(&Json::Bool(true)) => {
                        let code = doc.get("code").and_then(|c| c.as_str());
                        assert!(
                            code.is_none() || code == Some("partial"),
                            "an ok reply is full or explicitly partial: {doc:?}"
                        );
                        outcomes.push(if code.is_some() {
                            "partial".to_string()
                        } else {
                            "full".to_string()
                        });
                    }
                    Some(&Json::Bool(false)) => {
                        let code = doc
                            .get("code")
                            .and_then(|c| c.as_str())
                            .expect("refusals carry a code");
                        outcomes.push(format!("refused:{code}"));
                    }
                    other => panic!("reply without ok bool: {other:?}"),
                }
                std::thread::sleep(Duration::from_millis(20));
            }
            outcomes
        }));
    }
    let all: Vec<String> = joins
        .into_iter()
        .flat_map(|j| j.join().unwrap())
        .collect();
    assert_eq!(
        all.len(),
        36,
        "every logical request reached exactly one terminal outcome"
    );
    assert!(
        all.iter().any(|o| o == "ok"),
        "compute traffic survives the chaos: {all:?}"
    );
    assert!(
        all.iter().any(|o| o == "full" || o == "partial"),
        "scatter traffic survives the chaos: {all:?}"
    );
    front.shutdown();
    for s in shards {
        s.shutdown();
    }
}

#[test]
fn shard_hedged_scatter_masks_a_stalled_replica() {
    // The primary stalls every read by 300ms; the hedge fires after the
    // initial ~15ms delay and the healthy replica answers first. Queries
    // stay exact, and the hedge counters prove the mechanism (not luck)
    // served them. Probe timeout is raised above the stall so the slow
    // primary is slow, not dead — its breaker must stay closed.
    let slow = spawn_fleet_shard(0, 1, "slow_read_ms:300");
    let fast = spawn_fleet_shard(0, 1, "");
    let global = fleet_index(0, 1);
    let specs = fleet_specs(&[vec![slow.addr(), fast.addr()]]);
    let opts = RouterOptions {
        attempt_timeout: Duration::from_millis(900),
        scatter_budget: Duration::from_millis(2500),
        probe_interval: Duration::from_millis(50),
        probe_timeout: Duration::from_millis(700),
        hedge_initial: Duration::from_millis(15),
        ..RouterOptions::default()
    };
    let front = server::serve(
        Arc::new(ShardRouter::new(specs, opts)),
        "127.0.0.1:0",
        ServerOptions::default(),
    )
    .unwrap();
    for i in 0..6u64 {
        let q = Rng::new(5000 + i).unit_vec(N);
        let doc = fleet_request(front.addr(), &lsh_line(i, &q, K));
        assert_eq!(doc.get("ok"), Some(&Json::Bool(true)), "{doc:?}");
        assert!(
            doc.get("code").is_none(),
            "a hedged answer is a full answer: {doc:?}"
        );
        assert_eq!(result_pairs(&doc), global.query(&q, K));
    }
    let doc = fleet_request(front.addr(), "{\"id\": 1, \"op\": \"metrics\"}");
    let counters = doc
        .get("result")
        .and_then(|r| r.get("router"))
        .expect("metrics carry router counters");
    let hedges = counters.get("hedges").and_then(|v| v.as_f64()).unwrap();
    let wins = counters.get("hedge_wins").and_then(|v| v.as_f64()).unwrap();
    assert!(hedges >= 1.0, "the stalled primary must trigger hedges: {doc:?}");
    assert!(wins >= 1.0, "at least one hedge must beat the stalled primary: {doc:?}");
    front.shutdown();
    slow.shutdown();
    fast.shutdown();
}

// ---------------------------------------------------------------------------
// coalesce_*: the ingress (micro-batching + dedup + response cache) under
// faults. CI's batching lane runs these alongside tcp_serving's batch_*
// scenarios. The contract: coalescing and dedup are performance features
// only — degradation stays per-request. A dying leader fails over (every
// follower still reaches a terminal coded response), a poisoned row fails
// alone even when coalesced into a shared batch, and an admission refusal
// for one follower never evicts the leader's computation.
// ---------------------------------------------------------------------------

/// Start an ingress-fronted TCP server (dedup + response cache) over `be`.
fn serve_ingress(cfg: Config, be: Arc<dyn Backend>) -> (Arc<Coordinator>, TcpServer) {
    let c = Arc::new(Coordinator::start(cfg, be));
    let service: Arc<dyn LineService> = Arc::new(CoordinatorService::with_ingress(
        Arc::clone(&c),
        IngressOptions::default(),
    ));
    let srv = server::serve(service, "127.0.0.1:0", ServerOptions::default()).unwrap();
    (c, srv)
}

#[test]
fn coalesce_leader_death_fails_over_until_every_follower_terminates() {
    // Every backend call delays 200ms then panics: each dedup leader dies
    // mid-compute with followers subscribed to its slot. The orphaned slot
    // must wake them to retry — one promotes to leader, dies in turn — until
    // every client holds a terminal coded response. No reply may hang, and
    // no follower may be failed by a panic that wasn't its own attempt's.
    let be = faulty("panic:1,delay_ms:200,seed:2");
    let cfg = Config {
        breaker_threshold: 0,
        ..base_config()
    };
    let (c, srv) = serve_ingress(cfg, be as Arc<dyn Backend>);
    let addr = srv.addr();
    let clients = 4usize;
    let barrier = Arc::new(Barrier::new(clients));
    let vals: Vec<String> = (0..N).map(|i| format!("{}", i as f32 / 4.0)).collect();
    let line = format!("{{\"id\": 5, \"op\": \"transform\", \"vector\": [{}]}}\n", vals.join(","));
    let mut joins = Vec::new();
    for _ in 0..clients {
        let barrier = Arc::clone(&barrier);
        let line = line.clone();
        joins.push(std::thread::spawn(move || {
            let mut stream = TcpStream::connect(addr).unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            barrier.wait();
            stream.write_all(line.as_bytes()).unwrap();
            let mut resp = String::new();
            reader.read_line(&mut resp).unwrap();
            Json::parse(resp.trim()).expect("terminal reply despite leader death")
        }));
    }
    for j in joins {
        let doc = j.join().unwrap();
        assert_eq!(doc.get("ok"), Some(&Json::Bool(false)), "{doc}");
        assert_eq!(
            doc.get("code").unwrap().as_str(),
            Some("panic"),
            "followers of a dead leader must reach the typed outcome: {doc}"
        );
    }
    let m = c.lane_metrics(Op::Transform, N).expect("lane metrics");
    assert!(
        m.dedup_followers.load(Ordering::Relaxed) >= 1,
        "a 200ms compute window must catch at least one follower in flight"
    );
    srv.shutdown();
    drop(c);
}

/// Backend that panics whenever the batch contains a poisoned row (first
/// element above 900) — the coalesced-batch poison scenario.
struct PanickyBackend {
    inner: NativeBackend,
}

impl Backend for PanickyBackend {
    fn run_batch(&self, op: Op, n: usize, rows: usize, xs: &[f32]) -> Result<Output, String> {
        for row in xs.chunks_exact(n) {
            if row[0] > 900.0 {
                panic!("poisoned input row");
            }
        }
        self.inner.run_batch(op, n, rows, xs)
    }
    fn name(&self) -> &'static str {
        "panicky"
    }
}

#[test]
fn coalesce_poisoned_row_fails_alone_batchmates_match_uncoalesced() {
    // 8 concurrent clients with distinct vectors coalesce into shared
    // batches; one row is poisoned. The existing panic-singleton-retry
    // path must isolate it: the poisoned request wears code "panic", its
    // batchmates succeed byte-identically to an uncoalesced control server.
    let be = Arc::new(PanickyBackend {
        inner: NativeBackend::new(&[N], 1.0, 5),
    });
    let cfg = Config {
        max_batch: 8,
        max_wait: Duration::from_millis(100),
        breaker_threshold: 0,
        ..base_config()
    };
    let (c, srv) = serve_ingress(cfg, be as Arc<dyn Backend>);
    let addr = srv.addr();

    // uncoalesced control: same lane parameters, no ingress, no batching
    let control_c = Arc::new(Coordinator::start(
        Config {
            max_batch: 1,
            ..base_config()
        },
        native(),
    ));
    let control = TcpServer::start(Arc::clone(&control_c), "127.0.0.1:0").unwrap();
    let control_addr = control.addr();

    let clients = 8usize;
    let barrier = Arc::new(Barrier::new(clients));
    let mut joins = Vec::new();
    for t in 0..clients {
        let barrier = Arc::clone(&barrier);
        joins.push(std::thread::spawn(move || {
            let mut vals: Vec<String> = (0..N)
                .map(|i| format!("{}", (i + t * N) as f32 / 32.0 - 4.0))
                .collect();
            if t == 2 {
                vals[0] = "1000".into(); // the poisoned row
            }
            let line = format!(
                "{{\"id\": {t}, \"op\": \"transform\", \"vector\": [{}]}}\n",
                vals.join(",")
            );
            let mut stream = TcpStream::connect(addr).unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            barrier.wait();
            stream.write_all(line.as_bytes()).unwrap();
            let mut resp = String::new();
            reader.read_line(&mut resp).unwrap();
            // replay the same line against the uncoalesced control
            let mut cs = TcpStream::connect(control_addr).unwrap();
            let mut creader = BufReader::new(cs.try_clone().unwrap());
            cs.write_all(line.as_bytes()).unwrap();
            let mut control_resp = String::new();
            creader.read_line(&mut control_resp).unwrap();
            (t, resp, control_resp)
        }));
    }
    for j in joins {
        let (t, resp, control_resp) = j.join().unwrap();
        let doc = Json::parse(resp.trim()).unwrap();
        if t == 2 {
            assert_eq!(doc.get("ok"), Some(&Json::Bool(false)), "{doc}");
            assert_eq!(
                doc.get("code").unwrap().as_str(),
                Some("panic"),
                "the poisoned row wears its own panic: {doc}"
            );
        } else {
            assert_eq!(doc.get("ok"), Some(&Json::Bool(true)), "{doc}");
            assert_eq!(
                resp, control_resp,
                "a poisoned batchmate must not perturb client {t}'s bytes"
            );
        }
    }
    let m = c.lane_metrics(Op::Transform, N).expect("lane metrics");
    assert!(m.panics.load(Ordering::Relaxed) >= 1, "panic counted");
    assert_eq!(
        m.lane_failures.load(Ordering::Relaxed),
        0,
        "a poisoned coalesced row must not kill the lane"
    );
    control.shutdown();
    srv.shutdown();
    drop(c);
}

#[test]
fn coalesce_throttled_follower_does_not_evict_leader() {
    // Admission refusals are per-request even when requests are identical:
    // a follower whose token bucket is empty gets its `throttled` refusal
    // BEFORE joining the leader's slot, so the refusal can neither evict
    // the leader's in-flight computation nor poison the shared response.
    let per_req = triplespin::coordinator::admission::request_work(Op::Transform, N) as f64;
    let be = faulty("delay_ms:300");
    let cfg = Config {
        admission_rate: 0.0001, // effectively no refill within the test
        admission_burst: per_req + 1.0,
        ..base_config()
    };
    let (c, srv) = serve_ingress(cfg, be as Arc<dyn Backend>);
    let addr = srv.addr();
    let vals = |offset: f32| -> String {
        (0..N).map(|i| format!("{}", i as f32 + offset)).collect::<Vec<_>>().join(",")
    };

    // hog spends its whole budget on one (distinct) request
    let mut hog = TcpStream::connect(addr).unwrap();
    let mut hog_reader = BufReader::new(hog.try_clone().unwrap());
    hog.write_all(
        format!(
            "{{\"id\": 1, \"op\": \"transform\", \"client_id\": \"hog\", \"vector\": [{}]}}\n",
            vals(100.0)
        )
        .as_bytes(),
    )
    .unwrap();
    let mut resp = String::new();
    hog_reader.read_line(&mut resp).unwrap();
    assert_eq!(
        Json::parse(resp.trim()).unwrap().get("ok"),
        Some(&Json::Bool(true)),
        "{resp}"
    );

    // alice leads a fresh computation (300ms in the backend)
    let shared = format!(
        "{{\"id\": 2, \"op\": \"transform\", \"client_id\": \"alice\", \"vector\": [{}]}}\n",
        vals(0.0)
    );
    let mut alice = TcpStream::connect(addr).unwrap();
    let mut alice_reader = BufReader::new(alice.try_clone().unwrap());
    alice.write_all(shared.as_bytes()).unwrap();
    std::thread::sleep(Duration::from_millis(100)); // alice reaches the backend

    // hog sends the byte-identical request while alice is in flight: it
    // must bounce off admission with a typed, hinted refusal — immediately
    let over_budget = shared.replace("\"alice\"", "\"hog\"");
    let mut hog2 = TcpStream::connect(addr).unwrap();
    let mut hog2_reader = BufReader::new(hog2.try_clone().unwrap());
    let refused_at = Instant::now();
    hog2.write_all(over_budget.as_bytes()).unwrap();
    let mut refusal = String::new();
    hog2_reader.read_line(&mut refusal).unwrap();
    let doc = Json::parse(refusal.trim()).unwrap();
    assert_eq!(doc.get("ok"), Some(&Json::Bool(false)), "{doc}");
    assert_eq!(doc.get("code").unwrap().as_str(), Some("throttled"), "{doc}");
    assert!(doc.get("retry_after_ms").unwrap().as_f64().unwrap() > 0.0);
    assert!(
        refused_at.elapsed() < Duration::from_millis(200),
        "the refusal must not wait on the leader's backend time"
    );

    // the leader's computation survives the follower's refusal
    let mut reply = String::new();
    alice_reader.read_line(&mut reply).unwrap();
    let doc = Json::parse(reply.trim()).unwrap();
    assert_eq!(
        doc.get("ok"),
        Some(&Json::Bool(true)),
        "a throttled follower must not evict the leader: {doc}"
    );

    let m = c.lane_metrics(Op::Transform, N).expect("lane metrics");
    assert_eq!(
        m.dedup_followers.load(Ordering::Relaxed),
        0,
        "admission refuses before the slot join"
    );
    assert_eq!(
        m.cache_entries.load(Ordering::Relaxed),
        2,
        "both completed computations stay cached despite the refusal"
    );
    drop((hog_reader, hog, hog2_reader, hog2, alice_reader, alice));
    srv.shutdown();
    drop(c);
}
