//! Bit-for-bit SIMD/scalar equivalence suite.
//!
//! The SIMD layer's contract is that **every dispatch level computes
//! byte-identical outputs** — `TS_NO_SIMD=1`, a SIMD-less host and the
//! AVX2 build must be interchangeable down to the last bit. This suite
//! enforces it end to end: for every transform family × shape × batch
//! shape, `apply_into` and `apply_batch_into` run under every forcible
//! SIMD tier (the detected level, plus SSE2 on x86-64 — baseline there,
//! so AVX2-only CI runners still cover the SSE2 kernels) and under the
//! forced scalar level, and the outputs must be identical bytes. The
//! packed `SignDiag` diagonals are additionally checked against the
//! historical dense f32-diagonal reference, and the binary lane's
//! sign-quantized `binary::embed` codes are pinned against the naive
//! `sign(dense apply)` oracle at every tier (with the Hamming popcount
//! kernel cross-checked against `count_ones`).
//!
//! `simd::force` mutates process-global dispatch state, so everything runs
//! inside one `#[test]` (no intra-process races; the CI `TS_NO_SIMD=1`
//! lane separately runs the whole suite pinned to scalar).

use triplespin::binary::{BinaryEmbedding, BitMatrix};
use triplespin::linalg::fft::{self, ConvPlan, FftVariant};
use triplespin::linalg::simd;
use triplespin::runtime::WorkerPool;
use triplespin::transform::{make, make_square, Family, SignDiag, Transform};
use triplespin::util::rng::Rng;

const ALL_FAMILIES: [Family; 7] = [
    Family::Dense,
    Family::Hd3,
    Family::Hdg,
    Family::Circulant,
    Family::Toeplitz,
    Family::Hankel,
    Family::SkewCirculant,
];

/// Run `f` under the given dispatch level, restoring auto-detection after.
fn with_level<R>(level: Option<simd::Level>, f: impl FnOnce() -> R) -> R {
    simd::force(level);
    let r = f();
    simd::force(None);
    r
}

/// The non-scalar tiers to pit against the scalar oracle. Always the
/// detected level; on x86-64 additionally SSE2 (part of the architecture
/// baseline, so forcing it is always executable) — otherwise the SSE2
/// kernels would ship with zero coverage on AVX2-only CI runners.
fn levels_under_test() -> Vec<simd::Level> {
    let mut levels = vec![simd::level()];
    #[cfg(target_arch = "x86_64")]
    {
        if !levels.contains(&simd::Level::Sse2) {
            levels.push(simd::Level::Sse2);
        }
    }
    levels.retain(|l| *l != simd::Level::Scalar);
    levels
}

fn apply_all(t: &dyn Transform, x: &[f32]) -> Vec<f32> {
    let mut ws = t.make_workspace();
    let mut out = vec![0.0f32; t.dim_out()];
    t.apply_into(x, &mut out, &mut ws);
    out
}

fn apply_batch_all(t: &dyn Transform, xs: &[f32], rows: usize, pool: &WorkerPool) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * t.dim_out()];
    t.apply_batch_into(xs, &mut out, pool);
    out
}

fn check_family_equivalence() {
    let levels = levels_under_test();
    // shapes: square (small, odd-ish pow2, large enough for full SIMD
    // bodies + ragged batch rows) and stacked/truncated rectangles
    let dims = [4usize, 32, 256];
    let row_counts = [1usize, 3, 17, 40];
    let pool = WorkerPool::with_min_work(4, 0); // gate off: force the parallel path
    for fam in ALL_FAMILIES {
        for &n in &dims {
            let seed = 1000 + n as u64;
            // NOTE: constructors must be re-run per level only if they
            // depended on dispatch — they don't (construction is pure RNG +
            // f64 trig) — so one instance is shared across levels.
            let square = make_square(fam, n, &mut Rng::new(seed));
            let stacked = make(fam, n + n / 2 + 1, n, (n / 2).max(1), &mut Rng::new(seed));
            for t in [&square, &stacked] {
                let x = Rng::new(seed ^ 0xF00D).gaussian_vec(n);
                let scalar_out =
                    with_level(Some(simd::Level::Scalar), || apply_all(t.as_ref(), &x));
                for &level in &levels {
                    let simd_out = with_level(Some(level), || apply_all(t.as_ref(), &x));
                    assert_eq!(
                        simd_out,
                        scalar_out,
                        "{fam:?} n={n} {}: apply_into differs between {} and scalar",
                        t.name(),
                        level.name(),
                    );
                }
                for &rows in &row_counts {
                    let xs = Rng::new(seed ^ rows as u64).gaussian_vec(rows * n);
                    let scalar_out = with_level(Some(simd::Level::Scalar), || {
                        apply_batch_all(t.as_ref(), &xs, rows, &pool)
                    });
                    for &level in &levels {
                        let simd_out = with_level(Some(level), || {
                            apply_batch_all(t.as_ref(), &xs, rows, &pool)
                        });
                        assert_eq!(
                            simd_out,
                            scalar_out,
                            "{fam:?} n={n} rows={rows} {}: apply_batch_into differs between {} and scalar",
                            t.name(),
                            level.name(),
                        );
                    }
                }
            }
        }
    }
}

fn check_sign_diag_against_f32_reference() {
    // packed SignDiag application == the old dense Vec<f32> ±1 diagonal
    // multiply, bitwise, under every dispatch level
    let mut levels = levels_under_test();
    levels.push(simd::Level::Scalar);
    let mut rng = Rng::new(77);
    for n in [1usize, 31, 64, 100, 1024] {
        let dense = rng.rademacher_vec(n);
        let sd = SignDiag::from_f32(&dense);
        let x = rng.gaussian_vec(n);
        let mut reference = x.clone();
        for (v, s) in reference.iter_mut().zip(&dense) {
            *v *= *s;
        }
        for &level in &levels {
            let mut got = x.clone();
            with_level(Some(level), || sd.apply(&mut got));
            assert_eq!(got, reference, "n={n} level={}", level.name());
        }
        // scaled variant == multiplying by a ±s dense diagonal
        let s = 0.0625f32;
        let mut reference = x.clone();
        for (v, d) in reference.iter_mut().zip(&dense) {
            *v *= *d * s;
        }
        for &level in &levels {
            let mut got = x.clone();
            with_level(Some(level), || sd.apply_scaled(&mut got, s));
            assert_eq!(got, reference, "scaled n={n} level={}", level.name());
        }
    }
}

/// The RFFT engine's kernels — radix-4 butterflies, the fused
/// split/multiply/merge `cmul_half`, and the standalone split/merge —
/// must be byte-identical across every forcible dispatch tier, both at
/// the kernel level (via `rfft`/`irfft`/`ConvPlan`, which exercise
/// `fft_butterfly4` + `rfft_split`/`rfft_merge` + `cmul_half` end to end)
/// and for whole plans of both [`FftVariant`]s.
fn check_fft_kernel_equivalence() {
    let levels = levels_under_test();
    let mut rng = Rng::new(555);
    for lg in 0..=11usize {
        let n = 1usize << lg;
        let x: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let scalar_spec = with_level(Some(simd::Level::Scalar), || fft::rfft(&x));
        let scalar_back =
            with_level(Some(simd::Level::Scalar), || fft::irfft(&scalar_spec.0, &scalar_spec.1));
        for &level in &levels {
            let spec = with_level(Some(level), || fft::rfft(&x));
            assert_eq!(spec, scalar_spec, "rfft n={n} differs at {}", level.name());
            let back = with_level(Some(level), || fft::irfft(&spec.0, &spec.1));
            assert_eq!(back, scalar_back, "irfft n={n} differs at {}", level.name());
        }
        // whole plans, both engines, single-row + batch
        let kern: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let rows = 5;
        let xs: Vec<f64> = (0..rows * n).map(|_| rng.gaussian()).collect();
        for variant in [FftVariant::Rfft, FftVariant::Complex] {
            let plan = ConvPlan::with_variant(&kern, variant);
            let scalar_out = with_level(Some(simd::Level::Scalar), || {
                let mut re = xs.clone();
                let mut im = vec![0.0; plan.batch_scratch_len(rows)];
                plan.apply_batch_in_place(&mut re, &mut im);
                re
            });
            for &level in &levels {
                let simd_out = with_level(Some(level), || {
                    let mut re = xs.clone();
                    let mut im = vec![0.0; plan.batch_scratch_len(rows)];
                    plan.apply_batch_in_place(&mut re, &mut im);
                    re
                });
                assert_eq!(
                    simd_out,
                    scalar_out,
                    "ConvPlan {variant:?} n={n}: batch differs between {} and scalar",
                    level.name()
                );
            }
        }
    }
}

/// Sign-quantization contract: packed `binary::embed` must equal the
/// naive `sign(dense apply)` oracle bit for bit — for every family, square
/// and stacked shapes, single and pooled batch paths, at every forcible
/// SIMD tier (the transform output is tier-bit-identical and `pack_signs`
/// reads exactly the IEEE sign bit, so the codes must never vary).
fn check_binary_embed_equivalence() {
    let mut levels = levels_under_test();
    levels.push(simd::Level::Scalar);
    let row_counts = [1usize, 3, 17, 40];
    let pool = WorkerPool::with_min_work(4, 0); // gate off: force the parallel path
    for fam in ALL_FAMILIES {
        for &n in &[32usize, 128] {
            let seed = 9_000 + n as u64;
            let square = BinaryEmbedding::new(make_square(fam, n, &mut Rng::new(seed)));
            let stacked = BinaryEmbedding::new(make(
                fam,
                n + n / 2 + 1,
                n,
                (n / 2).max(1),
                &mut Rng::new(seed),
            ));
            for emb in [&square, &stacked] {
                // naive oracle: sign of the allocating dense apply path
                let x = Rng::new(seed ^ 0xBEEF).gaussian_vec(n);
                let y = with_level(Some(simd::Level::Scalar), || emb.transform().apply(&x));
                let mut naive = vec![0u64; emb.words_per_code()];
                for (i, v) in y.iter().enumerate() {
                    if v.is_sign_negative() {
                        naive[i / 64] |= 1 << (i % 64);
                    }
                }
                for &level in &levels {
                    let code = with_level(Some(level), || emb.embed(&x));
                    assert_eq!(
                        code.words(),
                        &naive[..],
                        "{fam:?} n={n} k={}: embed differs from sign(dense apply) at {}",
                        emb.code_bits(),
                        level.name(),
                    );
                }
                for &rows in &row_counts {
                    let xs = Rng::new(seed ^ rows as u64).gaussian_vec(rows * n);
                    let scalar_batch = with_level(Some(simd::Level::Scalar), || {
                        let mut m = BitMatrix::zeros(rows, emb.code_bits());
                        emb.embed_batch_into(&xs, &mut m, &pool);
                        m
                    });
                    // batch rows must equal the per-row embed path
                    for (r, row) in xs.chunks_exact(n).enumerate() {
                        let single = with_level(Some(simd::Level::Scalar), || emb.embed(row));
                        assert_eq!(
                            scalar_batch.row(r),
                            single.words(),
                            "{fam:?} n={n} rows={rows} r={r}: batch != per-row"
                        );
                    }
                    for &level in &levels {
                        let simd_batch = with_level(Some(level), || {
                            let mut m = BitMatrix::zeros(rows, emb.code_bits());
                            emb.embed_batch_into(&xs, &mut m, &pool);
                            m
                        });
                        assert_eq!(
                            simd_batch,
                            scalar_batch,
                            "{fam:?} n={n} rows={rows}: embed_batch differs between {} and scalar",
                            level.name(),
                        );
                    }
                }
            }
        }
    }
}

/// The popcount kernel must agree across tiers on the codes the embeddings
/// actually produce (integer arithmetic — any divergence is a kernel bug).
fn check_hamming_equivalence() {
    let mut levels = levels_under_test();
    levels.push(simd::Level::Scalar);
    let mut rng = Rng::new(4242);
    for words in [0usize, 1, 2, 3, 4, 5, 8, 17, 64] {
        let a: Vec<u64> = (0..words).map(|_| rng.next_u64()).collect();
        let b: Vec<u64> = (0..words).map(|_| rng.next_u64()).collect();
        let naive: u64 = a.iter().zip(&b).map(|(x, y)| (x ^ y).count_ones() as u64).sum();
        for &level in &levels {
            let got = with_level(Some(level), || simd::hamming(&a, &b));
            assert_eq!(got, naive, "hamming words={words} level={}", level.name());
        }
    }
}

/// Run `f` under scalar and under every tier, asserting identical results.
fn assert_tiers_match<T: PartialEq + std::fmt::Debug>(
    levels: &[simd::Level],
    name: &str,
    run: impl Fn() -> T,
) {
    let scalar = with_level(Some(simd::Level::Scalar), &run);
    for &level in levels {
        let got = with_level(Some(level), &run);
        assert_eq!(got, scalar, "{name}: differs between {} and scalar", level.name());
    }
}

fn bits32(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn bits64(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Every public SIMD kernel driven *directly*, not through the transform
/// stack: `cargo xtask lint` requires each `pub fn` kernel of
/// `linalg/simd.rs` to be named in this file, and this sweep is the
/// coverage backing that rule — a new kernel cannot land without per-tier
/// bit-identity here (comparisons are on raw IEEE bits, so `-0.0 == 0.0`
/// cannot mask a divergence). Length grids cover empty inputs,
/// sub-vector-width tails, exact vector multiples and sign-word straddles.
fn check_raw_kernel_equivalence() {
    let levels = levels_under_test();
    let mut rng = Rng::new(31337);
    let s32 = 0.37f32;

    // f32 kernels: butterfly, butterfly_scaled, scale, apply_signs,
    // apply_signs_scaled, promote_signs_scaled, pack_signs
    for n in [0usize, 1, 3, 4, 7, 8, 9, 15, 16, 31, 33, 64, 65, 100, 256] {
        let head = rng.gaussian_vec(n);
        let tail = rng.gaussian_vec(n);
        let diag = rng.gaussian_vec(n);
        let signs: Vec<u64> = (0..n.div_ceil(64)).map(|_| rng.next_u64()).collect();
        assert_tiers_match(&levels, &format!("butterfly n={n}"), || {
            let (mut h, mut t) = (head.clone(), tail.clone());
            simd::butterfly(&mut h, &mut t);
            (bits32(&h), bits32(&t))
        });
        assert_tiers_match(&levels, &format!("butterfly_scaled n={n}"), || {
            let (mut h, mut t) = (head.clone(), tail.clone());
            simd::butterfly_scaled(&mut h, &mut t, s32);
            (bits32(&h), bits32(&t))
        });
        assert_tiers_match(&levels, &format!("scale n={n}"), || {
            let mut a = head.clone();
            simd::scale(&mut a, &diag);
            bits32(&a)
        });
        assert_tiers_match(&levels, &format!("apply_signs n={n}"), || {
            let mut x = head.clone();
            simd::apply_signs(&mut x, &signs);
            bits32(&x)
        });
        assert_tiers_match(&levels, &format!("apply_signs_scaled n={n}"), || {
            let mut x = head.clone();
            simd::apply_signs_scaled(&mut x, &signs, s32);
            bits32(&x)
        });
        assert_tiers_match(&levels, &format!("promote_signs_scaled n={n}"), || {
            let mut dst = vec![0.0f64; n];
            simd::promote_signs_scaled(&head, &signs, s32, &mut dst);
            bits64(&dst)
        });
        assert_tiers_match(&levels, &format!("pack_signs n={n}"), || {
            let mut dst = vec![u64::MAX; n.div_ceil(64)];
            simd::pack_signs(&head, &mut dst);
            dst
        });
    }

    // f64 kernels: cmul, fft_butterfly, fft_butterfly4, cmul_half, and the
    // construction-path rfft_split / rfft_merge
    let gauss = |rng: &mut Rng, m: usize| -> Vec<f64> { (0..m).map(|_| rng.gaussian()).collect() };
    for n in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 16, 33, 64] {
        let a = gauss(&mut rng, n);
        let b = gauss(&mut rng, n);
        let c = gauss(&mut rng, n);
        let d = gauss(&mut rng, n);
        assert_tiers_match(&levels, &format!("cmul n={n}"), || {
            let (mut re, mut im) = (a.clone(), b.clone());
            simd::cmul(&mut re, &mut im, &c, &d);
            (bits64(&re), bits64(&im))
        });
        for stride in [1usize, 3] {
            for sign in [1.0f64, -1.0] {
                let tw = if n == 0 { 0 } else { (n - 1) * stride + 1 };
                let twr = gauss(&mut rng, tw);
                let twi = gauss(&mut rng, tw);
                let label = format!("fft_butterfly n={n} stride={stride} sign={sign}");
                assert_tiers_match(&levels, &label, || {
                    let (mut rh, mut ih) = (a.clone(), b.clone());
                    let (mut rt, mut it) = (c.clone(), d.clone());
                    simd::fft_butterfly(
                        &mut rh, &mut ih, &mut rt, &mut it, &twr, &twi, stride, sign,
                    );
                    (bits64(&rh), bits64(&ih), bits64(&rt), bits64(&it))
                });
                let tw4 = if n == 0 { 0 } else { 3 * (n - 1) * stride + 1 };
                let twr4 = gauss(&mut rng, tw4);
                let twi4 = gauss(&mut rng, tw4);
                let label = format!("fft_butterfly4 n={n} stride={stride} sign={sign}");
                let quads: Vec<Vec<f64>> = (0..8).map(|_| gauss(&mut rng, n)).collect();
                assert_tiers_match(&levels, &label, || {
                    let mut q: Vec<Vec<f64>> = quads.clone();
                    let (q0, rest) = q.split_at_mut(1);
                    let (q1, rest) = rest.split_at_mut(1);
                    let (q2, rest) = rest.split_at_mut(1);
                    let (q3, rest) = rest.split_at_mut(1);
                    let (q4, rest) = rest.split_at_mut(1);
                    let (q5, rest) = rest.split_at_mut(1);
                    let (q6, q7) = rest.split_at_mut(1);
                    simd::fft_butterfly4(
                        &mut q0[0], &mut q1[0], &mut q2[0], &mut q3[0], &mut q4[0], &mut q5[0],
                        &mut q6[0], &mut q7[0], &twr4, &twi4, stride, sign,
                    );
                    q.iter().map(|v| bits64(v)).collect::<Vec<_>>()
                });
            }
        }
        // half-spectrum kernels need even h (or the h <= 1 degenerate)
        if n <= 1 || n % 2 == 0 {
            let h = n;
            let kr = gauss(&mut rng, h + 1);
            let ki = gauss(&mut rng, h + 1);
            let twr = gauss(&mut rng, h / 2);
            let twi = gauss(&mut rng, h / 2);
            assert_tiers_match(&levels, &format!("cmul_half h={h}"), || {
                let (mut zre, mut zim) = (a.clone(), b.clone());
                simd::cmul_half(&mut zre, &mut zim, &kr, &ki, &twr, &twi);
                (bits64(&zre), bits64(&zim))
            });
            assert_tiers_match(&levels, &format!("rfft_split h={h}"), || {
                let (mut xr, mut xi) = (vec![0.0f64; h + 1], vec![0.0f64; h + 1]);
                simd::rfft_split(&a, &b, &mut xr, &mut xi, &twr, &twi);
                (bits64(&xr), bits64(&xi))
            });
            assert_tiers_match(&levels, &format!("rfft_merge h={h}"), || {
                let (mut zre, mut zim) = (vec![0.0f64; h], vec![0.0f64; h]);
                simd::rfft_merge(&kr, &ki, &mut zre, &mut zim, &twr, &twi);
                (bits64(&zre), bits64(&zim))
            });
        }
    }

    // hamming: integer popcount over XOR — sweep word counts around the
    // AVX2 4-word block boundary
    for words in [0usize, 1, 3, 4, 5, 8, 17] {
        let a: Vec<u64> = (0..words).map(|_| rng.next_u64()).collect();
        let b: Vec<u64> = (0..words).map(|_| rng.next_u64()).collect();
        assert_tiers_match(&levels, &format!("hamming words={words}"), || {
            simd::hamming(&a, &b)
        });
    }
}

#[test]
fn simd_and_scalar_paths_are_byte_identical() {
    println!(
        "detected SIMD level: {}; tiers under test vs scalar: {:?}",
        simd::level().name(),
        levels_under_test().iter().map(|l| l.name()).collect::<Vec<_>>()
    );
    check_raw_kernel_equivalence();
    check_sign_diag_against_f32_reference();
    check_fft_kernel_equivalence();
    check_family_equivalence();
    check_binary_embed_equivalence();
    check_hamming_equivalence();
}
