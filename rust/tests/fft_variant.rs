//! Cross-engine FFT variant suite.
//!
//! The execution engine ships two convolution engines: the default
//! real-input half-spectrum RFFT (radix-4) and the legacy full-complex
//! radix-2 path kept selectable via `TS_FFT=complex` (the CI cross-check
//! lane runs this whole test binary under that env). The two are NOT
//! bit-identical (different operation order), so this suite pins:
//!
//! * every FFT-backed transform family computes the same result on both
//!   engines to f64-round-off tolerance (single row and batch);
//! * `fft::variant()` honors the `TS_FFT` environment contract;
//! * plan construction is variant-stable: a plan built under one forced
//!   variant keeps producing deterministic, engine-consistent results
//!   after the global default changes.
//!
//! `fft::force_variant` mutates process-global construction state, so
//! everything runs inside one `#[test]`.

use triplespin::linalg::fft::{self, ConvPlan, FftVariant};
use triplespin::runtime::WorkerPool;
use triplespin::transform::{make_square, Family, Transform};
use triplespin::util::rng::Rng;

const FFT_FAMILIES: [Family; 4] = [
    Family::Circulant,
    Family::Toeplitz,
    Family::Hankel,
    Family::SkewCirculant,
];

fn with_variant<R>(v: FftVariant, f: impl FnOnce() -> R) -> R {
    fft::force_variant(Some(v));
    let r = f();
    fft::force_variant(None);
    r
}

fn env_contract() {
    // The cached default must reflect TS_FFT after a forced re-detect.
    fft::force_variant(None);
    let expect = match std::env::var("TS_FFT") {
        Ok(v) if v.eq_ignore_ascii_case("complex") => FftVariant::Complex,
        _ => FftVariant::Rfft,
    };
    assert_eq!(fft::variant(), expect, "TS_FFT contract violated");
}

fn families_agree_across_engines() {
    let pool = WorkerPool::with_min_work(2, 0);
    for fam in FFT_FAMILIES {
        for n in [4usize, 32, 256, 1024] {
            let seed = 4242 + n as u64;
            let t_r = with_variant(FftVariant::Rfft, || make_square(fam, n, &mut Rng::new(seed)));
            let t_c =
                with_variant(FftVariant::Complex, || make_square(fam, n, &mut Rng::new(seed)));
            let x = Rng::new(seed ^ 0xBEEF).gaussian_vec(n);
            let y_r = t_r.apply(&x);
            let y_c = t_c.apply(&x);
            for i in 0..n {
                let tol = 1e-3 * (1.0 + y_c[i].abs());
                assert!(
                    (y_r[i] - y_c[i]).abs() < tol,
                    "{fam:?} n={n} i={i}: rfft {} vs complex {}",
                    y_r[i],
                    y_c[i]
                );
            }
            // batch path through the pool: engines still agree row-wise
            let rows = 17;
            let xs = Rng::new(seed ^ 0xF00D).gaussian_vec(rows * n);
            let mut b_r = vec![0.0f32; rows * n];
            let mut b_c = vec![0.0f32; rows * n];
            t_r.apply_batch_into(&xs, &mut b_r, &pool);
            t_c.apply_batch_into(&xs, &mut b_c, &pool);
            for i in 0..rows * n {
                let tol = 1e-3 * (1.0 + b_c[i].abs());
                assert!(
                    (b_r[i] - b_c[i]).abs() < tol,
                    "{fam:?} n={n} batch i={i}: rfft {} vs complex {}",
                    b_r[i],
                    b_c[i]
                );
            }
        }
    }
}

fn plans_are_variant_stable() {
    // A plan captures its engine at construction: flipping the global
    // default afterwards must not change what it computes.
    let mut rng = Rng::new(7);
    let n = 128;
    let k: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
    let x: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
    let plan_r = with_variant(FftVariant::Rfft, || ConvPlan::new(&k));
    assert_eq!(plan_r.variant(), FftVariant::Rfft);
    let before = plan_r.apply(&x);
    let after = with_variant(FftVariant::Complex, || plan_r.apply(&x));
    assert_eq!(before, after, "plan output changed with the global default");
    // and the half-spectrum plan really checks out half the batch scratch
    assert_eq!(plan_r.batch_scratch_len(8), n);
    let plan_c = with_variant(FftVariant::Complex, || ConvPlan::new(&k));
    assert_eq!(plan_c.batch_scratch_len(8), 8 * n);
}

#[test]
fn fft_variants_are_interchangeable() {
    env_contract();
    plans_are_variant_stable();
    families_agree_across_engines();
}
