#!/usr/bin/env python3
"""Python mirror of `cargo xtask lint` (xtask/src/main.rs).

Why this exists: the authoring container has no Rust toolchain (see
CHANGES.md — every PR since PR 1 has hit this), so the repo-native
invariant linter cannot be *run* here even though it ships as a Rust
xtask. This file reimplements the same five rules over the same inputs so
the annotation backfill can be driven to a provably clean state locally;
CI runs the real `cargo xtask lint` as the authoritative gate.

Rules (keep in lockstep with xtask/src/main.rs — rule IDs match):

  R1  every line whose *code* (comments/strings stripped) contains the
      token `unsafe` must have a `// SAFETY:` comment on the same line or
      within the 8 preceding lines; `unsafe` may only appear at all in the
      allowlisted modules (linalg::simd, runtime::pool, binary, transform,
      kernels::features, coordinator::backend, util::signal).
  R2  every atomic-memory `Ordering::` use (Relaxed/Acquire/Release/
      AcqRel/SeqCst — std::cmp::Ordering is not matched) must have a
      `// ORDERING:` comment within the same 8-line window. Exempt, per
      the LaneMetrics carve-out: coordinator/metrics.rs itself, counter
      bumps whose receiver chain goes through `metrics` (the site line or
      its 2 preceding continuation lines mention `metrics`), and
      `#[cfg(test)]` modules.
  R3  every public SIMD kernel (`pub fn` at column 0 in linalg/simd.rs,
      minus the dispatch-introspection fns level/force/active) must be
      named in rust/tests/simd_equivalence.rs.
  R4  wire error codes — the `=> "..."` arms of the two `fn code()`
      bodies in coordinator/mod.rs plus the `CODE_*` consts in
      coordinator/server.rs — must be unique and exactly equal the set in
      ROADMAP.md's "Serving failure model" table.
  R5  every `take_f32_uninit` / `take_f64_uninit` call site outside
      linalg/workspace.rs (where they are defined and self-tested) and
      outside `#[cfg(test)]` modules must carry a `// OVERWRITE:` comment
      within the window.
  R6  rust/src/lib.rs must carry `#![deny(unsafe_op_in_unsafe_fn)]`.

Usage: python3 tools/lint_mirror.py [repo_root]   (exit 0 = clean)
"""

import re
import sys
from pathlib import Path

WINDOW = 8  # marker may sit on the site line or up to 8 lines above
# (8, not less: rationale blocks span several comment lines and one block
# legitimately covers the two or three stores of a single tiny method)

UNSAFE_ALLOWLIST = (
    "linalg/simd.rs",
    "runtime/pool.rs",
    "binary/",
    "transform/",
    "kernels/features.rs",
    "coordinator/backend.rs",
    "util/signal.rs",
)

ATOMIC_ORDERING = re.compile(r"\bOrdering::(Relaxed|Acquire|Release|AcqRel|SeqCst)\b")
UNSAFE_TOKEN = re.compile(r"\bunsafe\b")
TAKE_UNINIT = re.compile(r"\btake_f(?:32|64)_uninit\b")
KERNEL_ALLOWLIST = {"level", "force", "active"}


def strip_line(line, state):
    """Split one source line into (code, comment) given scanner state.

    state: dict with 'block_depth' (nested /* */) — Rust block comments
    nest. Strings and char literals are blanked out of the code part so a
    quote inside them cannot confuse comment detection; raw strings are
    handled for the r"..." form (no # guards are used in this repo).
    """
    code, comment = [], []
    i, n = 0, len(line)
    while i < n:
        c = line[i]
        nxt = line[i + 1] if i + 1 < n else ""
        if state["block_depth"] > 0:
            if c == "*" and nxt == "/":
                state["block_depth"] -= 1
                comment.append("*/")
                i += 2
            elif c == "/" and nxt == "*":
                state["block_depth"] += 1
                comment.append("/*")
                i += 2
            else:
                comment.append(c)
                i += 1
            continue
        if c == "/" and nxt == "/":
            comment.append(line[i:])
            break
        if c == "/" and nxt == "*":
            state["block_depth"] += 1
            comment.append("/*")
            i += 2
            continue
        if c == '"' or (c == "r" and nxt == '"'):
            if c == "r":
                code.append("r")
                i += 1
            # consume string literal (escapes only matter for non-raw, but
            # this repo's raw strings contain no quotes-after-backslash)
            code.append('""')
            i += 1
            while i < n:
                if line[i] == "\\" and i + 1 < n:
                    i += 2
                    continue
                if line[i] == '"':
                    i += 1
                    break
                i += 1
            continue
        if c == "'":
            # char literal or lifetime: 'a', '\n', '"' vs 'static
            m = re.match(r"'(\\.|[^\\'])'", line[i:])
            if m:
                code.append("' '")
                i += len(m.group(0))
                continue
            code.append(c)
            i += 1
            continue
        code.append(c)
        i += 1
    return "".join(code), "".join(comment)


def scan_file(path):
    """Return list of (code, comment, in_test_mod) per line."""
    state = {"block_depth": 0}
    rows = []
    pending_test_attr = False
    test_depth = None  # brace depth at which the test mod closes
    depth = 0
    for raw in path.read_text().splitlines():
        code, comment = strip_line(raw, state)
        stripped = code.strip()
        in_test = test_depth is not None
        if test_depth is None:
            if re.search(r"#\[cfg\((all\()?(test|miri)\b", stripped):
                pending_test_attr = True
            elif pending_test_attr and stripped.startswith("mod "):
                test_depth = depth
                in_test = True
                pending_test_attr = False
            elif stripped and not stripped.startswith("#["):
                pending_test_attr = False
        depth += code.count("{") - code.count("}")
        if test_depth is not None and depth <= test_depth and "}" in code:
            # the closing brace line itself still counts as test code
            rows.append((code, comment, True))
            test_depth = None
            continue
        rows.append((code, comment, in_test))
    return rows


def has_marker(rows, idx, marker):
    for j in range(idx, max(-1, idx - WINDOW - 1), -1):
        if marker in rows[j][1]:
            return True
        # stop once we walk past a non-adjacent code statement boundary:
        # a line that is pure code with no comment and no continuation
        # would still be within the same statement, so we only bound by
        # the fixed window (see module docstring).
    return False


def main():
    root = Path(sys.argv[1]) if len(sys.argv) > 1 else Path(".")
    src = root / "rust" / "src"
    errors = []

    # ---- R1 / R2 / R5: annotation rules over rust/src ----
    for path in sorted(src.rglob("*.rs")):
        rel = path.relative_to(src).as_posix()
        rows = scan_file(path)
        allowed_unsafe = any(
            rel == a or (a.endswith("/") and rel.startswith(a)) for a in UNSAFE_ALLOWLIST
        )
        for i, (code, comment, in_test) in enumerate(rows):
            loc = f"rust/src/{rel}:{i + 1}"
            if UNSAFE_TOKEN.search(code):
                if not allowed_unsafe:
                    errors.append(f"R1 {loc}: `unsafe` outside the module allowlist")
                if not has_marker(rows, i, "SAFETY:"):
                    errors.append(f"R1 {loc}: `unsafe` without an adjacent // SAFETY: comment")
            metrics_recv = any("metrics" in rows[j][0] for j in range(max(0, i - 2), i + 1))
            if (
                ATOMIC_ORDERING.search(code)
                and rel != "coordinator/metrics.rs"
                and not metrics_recv
                and not in_test
                and not has_marker(rows, i, "ORDERING:")
            ):
                errors.append(f"R2 {loc}: atomic Ordering:: without an adjacent // ORDERING: comment")
            if (
                TAKE_UNINIT.search(code)
                and rel != "linalg/workspace.rs"
                and not in_test
                and not has_marker(rows, i, "OVERWRITE:")
            ):
                errors.append(f"R5 {loc}: take_*_uninit without an adjacent // OVERWRITE: comment")

    # ---- R3: public SIMD kernels must appear in the equivalence suite ----
    simd = (src / "linalg" / "simd.rs").read_text()
    kernels = [
        m.group(1)
        for m in re.finditer(r"^pub fn (\w+)", simd, re.M)
        if m.group(1) not in KERNEL_ALLOWLIST
    ]
    equiv = (root / "rust" / "tests" / "simd_equivalence.rs").read_text()
    for k in kernels:
        if not re.search(rf"\b{k}\b", equiv):
            errors.append(
                f"R3 rust/src/linalg/simd.rs: public kernel `{k}` is not exercised by "
                f"rust/tests/simd_equivalence.rs"
            )

    # ---- R4: wire codes unique + exactly the ROADMAP table set ----
    coord = (src / "coordinator" / "mod.rs").read_text()
    codes = []
    for body in re.finditer(r"fn code\(&self\) -> &'static str \{(.*?)\n    \}", coord, re.S):
        codes += re.findall(r'=> "([a-z_]+)"', body.group(1))
    # CODE_* consts live in codec.rs since the codec split; scan server.rs
    # too so a straggler const is still part of the taxonomy
    server = (src / "coordinator" / "server.rs").read_text()
    server += (src / "coordinator" / "codec.rs").read_text()
    codes += re.findall(r'const CODE_[A-Z_]+: &str = "([a-z_]+)";', server)
    if len(codes) != len(set(codes)):
        dupes = sorted({c for c in codes if codes.count(c) > 1})
        errors.append(f"R4 coordinator: duplicate wire codes: {dupes}")
    roadmap = (root / "ROADMAP.md").read_text()
    table = re.findall(r"^\| `([a-z_]+)` \|", roadmap, re.M)
    if len(table) != len(set(table)):
        errors.append("R4 ROADMAP.md: duplicate rows in the failure-model table")
    missing = sorted(set(codes) - set(table))
    stale = sorted(set(table) - set(codes))
    if missing:
        errors.append(f"R4 ROADMAP.md: failure-model table is missing wire codes {missing}")
    if stale:
        errors.append(f"R4 ROADMAP.md: failure-model table lists unknown codes {stale}")

    # ---- R6: the deny attribute that makes R1 sound for unsafe fns ----
    lib = (src / "lib.rs").read_text()
    if "#![deny(unsafe_op_in_unsafe_fn)]" not in lib:
        errors.append("R6 rust/src/lib.rs: missing #![deny(unsafe_op_in_unsafe_fn)]")

    for e in errors:
        print(e)
    print(f"lint_mirror: {len(errors)} violation(s), {len(kernels)} kernels, {len(codes)} wire codes")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
